//! The detlint rules: seven determinism / conservation / shard-safety
//! lints over the token streams produced by `lexer`, plus the
//! `detlint:allow` suppression protocol.
//!
//! - `unordered_container` (L1): no `HashMap` / `HashSet` in simulation
//!   modules — iteration order is randomized per process, so any order
//!   that reaches simulation state or output breaks same-seed
//!   byte-identical runs.
//! - `wall_clock` (L2): no `Instant` / `SystemTime` / `thread_rng` /
//!   environment reads outside the `hostclock` seam — the virtual
//!   timeline must never observe the host.
//! - `raw_event_key` (L3): event ordering must go through the derived
//!   `(time, seq)` `EventKey` — hand-written `Ord` impls and float-keyed
//!   heaps in simulation modules are flagged.
//! - `unaudited_stats` (L4): every `pub struct *Stats` must be named by
//!   at least one conservation test or `check_invariants` / `audit` body,
//!   so a counter can't drift without a test noticing.
//! - `undeclared_shared_state` (L5): every cross-module
//!   `Rc<RefCell<T>>` handle (per the `graph` state-access pass) must
//!   have a `[state.T]` entry in `xtask/shard_map.toml` naming its
//!   owning module and shard domain; the map's owner fields must match
//!   the graph, and stale entries are flagged too.
//! - `cross_shard_mut` (L6): no `per_worker` module may mutate state
//!   owned by a *different* `per_worker` domain except through the
//!   `netpath` wire seam — the invariant a sharded engine relies on.
//! - `tie_break_sensitive` (L7): schedule calls whose firing order is
//!   decided by the engine's same-timestamp tie-break — loop-invariant
//!   timestamps in a `for` body, and `.after(0, ..)` — must carry a
//!   `// tie-break:` ordering rationale within three lines.
//!
//! Suppression is a single pass over *all* raw violations from *all*
//! lints, so an allow consumed by one lint is never reported stale by
//! another, and violations against files the scanner did not lex (the
//! shard map itself) flow through instead of being dropped.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::graph::{is_builtin, skip_braces, StateGraph};
use crate::lexer::{Lexed, Token};
use crate::shard_map::ShardMap;

pub const LINT_NAMES: [&str; 7] = [
    "unordered_container",
    "wall_clock",
    "raw_event_key",
    "unaudited_stats",
    "undeclared_shared_state",
    "cross_shard_mut",
    "tie_break_sensitive",
];

/// How a file participates in the analysis; decided by `scan` from its
/// path (repo layout) or forced by fixture mode.
#[derive(Debug, Clone, Copy, Default)]
pub struct FileClass {
    /// Simulation module: L1, L3 and L7 apply.
    pub sim: bool,
    /// The one allowlisted host seam (`src/hostclock.rs`): L2 exempt.
    pub hostclock: bool,
    /// `pub struct *Stats` definitions here must be audited (L4).
    pub stats_defs: bool,
    /// The whole file counts as audited context for L4 (tests, benches).
    pub audited: bool,
}

/// One lexed source file ready for analysis.
#[derive(Debug)]
pub struct SourceFile {
    /// Path as reported in diagnostics (relative to the crate root).
    pub path: PathBuf,
    pub class: FileClass,
    /// Module name for the state-access graph: the top-level directory
    /// under `src/` for sim modules in repo mode, the file stem in
    /// fixture mode, `None` for files outside the graph.
    pub module: Option<String>,
    pub lexed: Lexed,
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub file: PathBuf,
    pub line: u32,
    pub lint: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file.display(), self.line, self.lint, self.msg)
    }
}

/// Load a shard map, converting parse errors into violations against
/// the map file itself. `Ok(None)` means the file does not exist.
pub fn load_map(path: &Path) -> Result<Option<ShardMap>, Vec<Violation>> {
    crate::shard_map::load(path).map_err(|errs| {
        errs.into_iter()
            .map(|(line, msg)| Violation {
                file: path.to_path_buf(),
                line,
                lint: "shard_map",
                msg,
            })
            .collect()
    })
}

/// Run every lint over `files` and apply suppressions. Returned
/// violations are sorted by (file, line, lint) and deduplicated per line
/// so one `HashMap<K, V> = HashMap::new()` line reports once. The shard
/// lints (L5/L6) only run when a shard map is present; repo mode always
/// passes one, fixture dirs may omit it.
pub fn run(files: &[SourceFile], map: Option<&ShardMap>) -> Vec<Violation> {
    let mut raw: Vec<Violation> = Vec::new();
    for sf in files {
        lint_unordered_container(sf, &mut raw);
        lint_wall_clock(sf, &mut raw);
        lint_raw_event_key(sf, &mut raw);
        lint_tie_break(sf, &mut raw);
    }
    lint_unaudited_stats(files, &mut raw);
    lint_shard_state(files, map, &mut raw);
    suppress(files, raw)
}

/// The single suppression pass: every raw violation from every lint is
/// checked against the allows of the file it points at. An allow
/// suppresses a violation on its own line or on the line directly below
/// it (comment-above style); one allow may absorb hits from several
/// lint passes and counts as used after the first. Unused allows are
/// violations themselves: a stale suppression is a trap. Violations
/// against files with no lexed source (the shard map) pass through —
/// they cannot be suppressed, only fixed.
fn suppress(files: &[SourceFile], raw: Vec<Violation>) -> Vec<Violation> {
    let by_path: BTreeMap<&Path, &SourceFile> =
        files.iter().map(|sf| (sf.path.as_path(), sf)).collect();
    let mut used: BTreeMap<&Path, Vec<bool>> = files
        .iter()
        .map(|sf| (sf.path.as_path(), vec![false; sf.lexed.allows.len()]))
        .collect();
    let mut out: Vec<Violation> = Vec::new();
    let mut seen: BTreeSet<(PathBuf, u32, &'static str)> = BTreeSet::new();
    for v in &raw {
        let mut suppressed = false;
        if let Some(sf) = by_path.get(v.file.as_path()) {
            let flags = used.get_mut(v.file.as_path()).expect("same key set");
            for (ai, a) in sf.lexed.allows.iter().enumerate() {
                if a.lint == v.lint && (a.line == v.line || a.line + 1 == v.line) {
                    flags[ai] = true;
                    suppressed = true;
                }
            }
        }
        if !suppressed && seen.insert((v.file.clone(), v.line, v.lint)) {
            out.push(v.clone());
        }
    }
    for sf in files {
        let flags = &used[sf.path.as_path()];
        for (ai, a) in sf.lexed.allows.iter().enumerate() {
            if !LINT_NAMES.contains(&a.lint.as_str()) {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: a.line,
                    lint: "bad_allow",
                    msg: format!("unknown lint {:?} in detlint:allow", a.lint),
                });
            } else if !flags[ai] {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: a.line,
                    lint: "unused_allow",
                    msg: format!("detlint:allow({}) suppresses nothing here", a.lint),
                });
            }
        }
        for (line, msg) in &sf.lexed.bad_allows {
            out.push(Violation {
                file: sf.path.clone(),
                line: *line,
                lint: "bad_allow",
                msg: msg.clone(),
            });
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    out
}

/// L1: randomized-order containers in simulation modules.
fn lint_unordered_container(sf: &SourceFile, out: &mut Vec<Violation>) {
    if !sf.class.sim {
        return;
    }
    for t in &sf.lexed.tokens {
        if t.text == "HashMap" || t.text == "HashSet" {
            out.push(Violation {
                file: sf.path.clone(),
                line: t.line,
                lint: "unordered_container",
                msg: format!(
                    "{} in a simulation module: iteration order is per-process random and \
                     breaks same-seed determinism; use BTreeMap/BTreeSet or an indexed Vec",
                    t.text
                ),
            });
        }
    }
}

/// L2: host clock / entropy / environment reads outside `hostclock`.
fn lint_wall_clock(sf: &SourceFile, out: &mut Vec<Violation>) {
    if sf.class.hostclock {
        return;
    }
    let toks = &sf.lexed.tokens;
    let mut push = |line: u32, what: &str| {
        out.push(Violation {
            file: sf.path.clone(),
            line,
            lint: "wall_clock",
            msg: format!(
                "{what} outside the hostclock seam: the virtual timeline must not observe \
                 the host; route through crate::hostclock (bench wall-clock reporting only)"
            ),
        });
    };
    for (i, t) in toks.iter().enumerate() {
        match t.text.as_str() {
            "Instant" => push(t.line, "std::time::Instant"),
            "SystemTime" => push(t.line, "std::time::SystemTime"),
            "thread_rng" => push(t.line, "thread_rng (nondeterministic entropy)"),
            "rand" if toks.get(i + 1).map(|n| n.text.as_str()) == Some("::") => {
                push(t.line, "the rand crate (nondeterministic entropy)");
            }
            "env" => {
                // std::env::var / var_os / vars / vars_os are host state;
                // env::args (CLI input) and the compile-time env! macro
                // are fine.
                let nx = toks.get(i + 1).map(|n| n.text.as_str());
                let nx2 = toks.get(i + 2).map(|n| n.text.as_str());
                if nx == Some("::") && matches!(nx2, Some("var" | "var_os" | "vars" | "vars_os"))
                {
                    push(t.line, "an environment read");
                }
            }
            _ => {}
        }
    }
}

/// L3: hand-rolled ordering in simulation modules — `impl Ord /
/// PartialOrd for …` and float-keyed `BinaryHeap`s. The derived
/// `(time, seq)` `EventKey` is the only sanctioned event order.
fn lint_raw_event_key(sf: &SourceFile, out: &mut Vec<Violation>) {
    if !sf.class.sim {
        return;
    }
    let toks = &sf.lexed.tokens;
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text == "impl" {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text.as_str()) == Some("<") {
                j = skip_angle_brackets(toks, j);
            }
            if let Some(t) = toks.get(j) {
                if (t.text == "Ord" || t.text == "PartialOrd")
                    && toks.get(j + 1).map(|n| n.text.as_str()) == Some("for")
                {
                    out.push(Violation {
                        file: sf.path.clone(),
                        line: t.line,
                        lint: "raw_event_key",
                        msg: format!(
                            "hand-written {} impl in a simulation module: event ordering must \
                             use the derived (time, seq) EventKey, not ad-hoc comparisons",
                            t.text
                        ),
                    });
                }
            }
        } else if toks[i].text == "BinaryHeap"
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("<")
        {
            let end = skip_angle_brackets(toks, i + 1);
            if toks[i + 1..end.min(toks.len())]
                .iter()
                .any(|t| t.text == "f64" || t.text == "f32")
            {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: toks[i].line,
                    lint: "raw_event_key",
                    msg: "float-keyed BinaryHeap in a simulation module: floats have no total \
                          order and ties are seed-visible; key events by the derived (time, seq) \
                          EventKey"
                        .to_string(),
                });
            }
        }
        i += 1;
    }
}

/// Skip a balanced `<…>` region starting at the `<` at index `open`;
/// returns the index just past the matching `>`.
fn skip_angle_brackets(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "<" => depth += 1,
            ">" => {
                depth -= 1;
                if depth <= 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    k
}

/// L4: every `pub struct *Stats` definition must be referenced — by type
/// name or snake_case name — inside audited context: a test file, a
/// bench, a `#[cfg(test)]` region, or the body of a `check_invariants` /
/// `audit` / `audit_into` / `audit_tree` fn.
fn lint_unaudited_stats(files: &[SourceFile], out: &mut Vec<Violation>) {
    let mut defs: Vec<(PathBuf, u32, String)> = Vec::new();
    for sf in files {
        if !sf.class.stats_defs {
            continue;
        }
        let toks = &sf.lexed.tokens;
        for i in 0..toks.len() {
            if toks[i].text == "pub" && toks.get(i + 1).map(|t| t.text.as_str()) == Some("struct")
            {
                if let Some(name) = toks.get(i + 2) {
                    if name.text.ends_with("Stats") {
                        defs.push((sf.path.clone(), name.line, name.text.clone()));
                    }
                }
            }
        }
    }
    if defs.is_empty() {
        return;
    }

    let mut audited: BTreeSet<String> = BTreeSet::new();
    for sf in files {
        collect_audited(sf, &mut audited);
    }

    for (file, line, name) in defs {
        let snake = snake_case(&name);
        if !audited.contains(&name) && !audited.contains(&snake) {
            out.push(Violation {
                file,
                line,
                lint: "unaudited_stats",
                msg: format!(
                    "pub struct {name} is not referenced by any conservation test or \
                     check_invariants/audit impl; counters that nothing checks drift silently"
                ),
            });
        }
    }
}

/// Gather the audited-context token set from one file.
fn collect_audited(sf: &SourceFile, audited: &mut BTreeSet<String>) {
    let toks = &sf.lexed.tokens;
    if sf.class.audited {
        for t in toks {
            audited.insert(t.text.clone());
        }
        return;
    }
    // #[cfg(test)] to end of file. An approximation of module scope, but
    // in this crate the test module is always the tail of the file, and
    // widening the audited region only ever errs toward acceptance.
    let mut i = 0;
    while i + 4 < toks.len() {
        if toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
        {
            for t in &toks[i..] {
                audited.insert(t.text.clone());
            }
            break;
        }
        i += 1;
    }
    // Bodies of invariant-auditing fns.
    let mut i = 0;
    while i + 1 < toks.len() {
        if toks[i].text == "fn"
            && matches!(
                toks[i + 1].text.as_str(),
                "check_invariants" | "audit" | "audit_into" | "audit_tree"
            )
        {
            let mut k = i + 2;
            while k < toks.len() && toks[k].text != "{" {
                k += 1;
            }
            let mut depth = 0i32;
            while k < toks.len() {
                match toks[k].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                audited.insert(toks[k].text.clone());
                k += 1;
            }
            i = k;
        }
        i += 1;
    }
}

fn snake_case(name: &str) -> String {
    let mut s = String::new();
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() {
            if i > 0 {
                s.push('_');
            }
            s.push(c.to_ascii_lowercase());
        } else {
            s.push(c);
        }
    }
    s
}

/// L5 + L6: shard-safety over the state-access graph.
///
/// L5 (`undeclared_shared_state`): a module holding a named, non-builtin
/// `Rc<RefCell<T>>` whose defining module is *not* itself must find a
/// `[state.T]` declaration in the shard map; the declaration's `owner`
/// must match the graph's definition site; a declaration no handle
/// references is stale; and every module participating in declared state
/// must have a `[modules]` domain entry.
///
/// L6 (`cross_shard_mut`): a `per_worker` module mutating
/// (`.borrow_mut()`) declared `per_worker` state owned by a different
/// module is flagged unless either side is the `netpath` wire seam.
fn lint_shard_state(files: &[SourceFile], map: Option<&ShardMap>, out: &mut Vec<Violation>) {
    let Some(map) = map else { return };
    let graph = StateGraph::build(files);
    let mut referenced: BTreeSet<&str> = BTreeSet::new();
    for (m, acc) in &graph.modules {
        for h in &acc.handles {
            if is_builtin(&h.inner) {
                continue;
            }
            referenced.insert(h.inner.as_str());
            let owner = graph.def_site(&h.inner);
            if owner == Some(m.as_str()) {
                continue;
            }
            match map.state.get(&h.inner) {
                Some(decl) => {
                    if !map.modules.contains_key(m) {
                        let ty = &h.inner;
                        out.push(Violation {
                            file: map.path.clone(),
                            line: decl.line,
                            lint: "undeclared_shared_state",
                            msg: format!(
                                "module `{m}` holds declared state {ty} but has no [modules] \
                                 entry in the shard map"
                            ),
                        });
                    }
                }
                None => {
                    let owner = owner.unwrap_or("unknown");
                    let ty = &h.inner;
                    out.push(Violation {
                        file: h.file.clone(),
                        line: h.line,
                        lint: "undeclared_shared_state",
                        msg: format!(
                            "module `{m}` holds a cross-module Rc<RefCell<{ty}>> (defining \
                             module: {owner}) with no [state.{ty}] entry in shard_map.toml; \
                             declare its owning shard domain"
                        ),
                    });
                }
            }
        }
    }
    for (ty, decl) in &map.state {
        if let Some(actual) = graph.def_site(ty) {
            if actual != decl.owner {
                let o = &decl.owner;
                out.push(Violation {
                    file: map.path.clone(),
                    line: decl.line,
                    lint: "undeclared_shared_state",
                    msg: format!(
                        "[state.{ty}] declares owner \"{o}\" but {ty} is defined in module \
                         `{actual}`"
                    ),
                });
            }
        }
        if !map.modules.contains_key(&decl.owner) {
            let o = &decl.owner;
            out.push(Violation {
                file: map.path.clone(),
                line: decl.line,
                lint: "undeclared_shared_state",
                msg: format!(
                    "owner module `{o}` of [state.{ty}] has no [modules] entry in the shard map"
                ),
            });
        }
        if !referenced.contains(ty.as_str()) {
            out.push(Violation {
                file: map.path.clone(),
                line: decl.line,
                lint: "undeclared_shared_state",
                msg: format!(
                    "[state.{ty}] matches no Rc<RefCell<{ty}>> handle in any scanned module; \
                     stale entries mask real gaps — delete it"
                ),
            });
        }
    }
    for (m, acc) in &graph.modules {
        if m == "netpath" {
            continue;
        }
        let Some((domain, _)) = map.modules.get(m) else { continue };
        if domain != "per_worker" {
            continue;
        }
        for mu in &acc.mutations {
            let Some(decl) = map.state.get(&mu.inner) else { continue };
            if decl.domain == "per_worker" && decl.owner != *m && decl.owner != "netpath" {
                let (ty, o) = (&mu.inner, &decl.owner);
                out.push(Violation {
                    file: mu.file.clone(),
                    line: mu.line,
                    lint: "cross_shard_mut",
                    msg: format!(
                        "per_worker module `{m}` mutates {ty} owned by per_worker module `{o}`: \
                         cross-shard mutation must cross the netpath wire seam, not a shared \
                         handle"
                    ),
                });
            }
        }
    }
}

/// One active `for` loop surrounding the current token position.
struct LoopFrame {
    /// The loop pattern's idents plus every ident assigned in the body —
    /// a timestamp derived from either varies per iteration.
    vars: BTreeSet<String>,
    /// Token index just past the body's closing brace.
    end: usize,
    /// The body constructs a `Sim::…` — a fresh per-iteration engine,
    /// so same-instant schedules cannot tie across iterations.
    fresh_sim: bool,
}

const SCHED_CALLS: [&str; 4] = ["at", "at_handle", "after", "after_handle"];

/// L7: tie-break-sensitive schedule calls in simulation modules.
///
/// Rule A: a `.at/.after(..)` call inside a `for` body whose time
/// argument mentions no per-iteration variable — every iteration lands
/// on the same instant, and the firing order among those events is
/// whatever the engine's tie-break policy says.
///
/// Rule B: `.after(0, ..)` — scheduling at the *current* instant races
/// against everything already queued for that timestamp.
///
/// Both are legitimate patterns when the order genuinely does not matter
/// (or is itself under test); the lint demands that the author say so in
/// a `// tie-break:` comment on the call line or within the three lines
/// above it, or via `detlint:allow(tie_break_sensitive, …)`.
fn lint_tie_break(sf: &SourceFile, out: &mut Vec<Violation>) {
    if !sf.class.sim {
        return;
    }
    let toks = &sf.lexed.tokens;
    let rationales = &sf.lexed.rationales;
    let excused = |line: u32| rationales.iter().any(|&r| r <= line && line <= r + 3);
    let mut frames: Vec<LoopFrame> = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        while frames.last().is_some_and(|f| f.end <= i) {
            frames.pop();
        }
        if toks[i].text == "for" && is_loop_for(toks, i) {
            if let Some(frame) = parse_for_frame(toks, i) {
                frames.push(frame);
            }
        } else if let Some(frame) = frames.last_mut() {
            if toks[i].text == "Sim" && toks.get(i + 1).is_some_and(|n| n.text == "::") {
                frame.fresh_sim = true;
            }
            track_frame_vars(toks, i, frame);
        }
        if toks[i].text == "."
            && toks.get(i + 1).is_some_and(|n| SCHED_CALLS.contains(&n.text.as_str()))
            && toks.get(i + 2).is_some_and(|n| n.text == "(")
        {
            let name = toks[i + 1].text.clone();
            let line = toks[i + 1].line;
            let immediate = (name == "after" || name == "after_handle")
                && toks.get(i + 3).is_some_and(|n| n.text == "0");
            if immediate {
                if !excused(line) {
                    out.push(Violation {
                        file: sf.path.clone(),
                        line,
                        lint: "tie_break_sensitive",
                        msg: format!(
                            ".{name}(0, ..) schedules at the current instant and races \
                             already-queued same-time events under a permuted tie-break; \
                             state the ordering rationale in a `// tie-break:` comment"
                        ),
                    });
                }
            } else if !frames.is_empty() && !frames.last().is_some_and(|f| f.fresh_sim) {
                let args = first_arg_idents(toks, i + 3);
                let varies =
                    args.iter().any(|a| frames.iter().any(|f| f.vars.contains(a.as_str())));
                if !varies && !excused(line) {
                    out.push(Violation {
                        file: sf.path.clone(),
                        line,
                        lint: "tie_break_sensitive",
                        msg: format!(
                            ".{name}(..) in a loop at a loop-invariant timestamp: every \
                             iteration lands on the same instant and fires in tie-break order; \
                             vary the time or state the rationale in a `// tie-break:` comment"
                        ),
                    });
                }
            }
        }
        i += 1;
    }
}

/// Is the `for` at `i` a loop (not `impl … for T` / `for<'a>`)?
fn is_loop_for(toks: &[Token], i: usize) -> bool {
    if toks.get(i + 1).is_some_and(|n| n.text == "<") {
        return false;
    }
    match i.checked_sub(1).and_then(|p| toks.get(p)) {
        Some(p) => !(is_ident_text(&p.text) || p.text == ">"),
        None => true,
    }
}

fn is_ident_text(t: &str) -> bool {
    t.chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Parse the loop at token `i` into a frame: pattern idents + body span.
fn parse_for_frame(toks: &[Token], i: usize) -> Option<LoopFrame> {
    let mut vars = BTreeSet::new();
    let mut j = i + 1;
    while j < toks.len() && toks[j].text != "in" {
        if j > i + 32 {
            return None; // not a loop shape we understand
        }
        if is_ident_text(&toks[j].text) && toks[j].text != "mut" {
            vars.insert(toks[j].text.clone());
        }
        j += 1;
    }
    // Body: the first `{` after `in` at paren/bracket depth 0 (a `{` in
    // a closure argument of the iterator chain sits inside parens).
    let mut depth = 0i32;
    let mut k = j + 1;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            "{" if depth == 0 => break,
            _ => {}
        }
        k += 1;
    }
    if k >= toks.len() {
        return None;
    }
    Some(LoopFrame { vars, end: skip_braces(toks, k), fresh_sim: false })
}

/// Record idents the innermost loop body assigns (`x = …`, `x += …`,
/// `let (a, b) = …`): they vary per iteration like the loop pattern.
fn track_frame_vars(toks: &[Token], i: usize, frame: &mut LoopFrame) {
    let t = &toks[i];
    if t.text == "let" && toks.get(i + 1).is_some_and(|n| n.text == "(") {
        let mut k = i + 2;
        while k < toks.len() && toks[k].text != ")" {
            if is_ident_text(&toks[k].text) && toks[k].text != "mut" {
                frame.vars.insert(toks[k].text.clone());
            }
            k += 1;
        }
        return;
    }
    if !is_ident_text(&t.text) {
        return;
    }
    let n1 = toks.get(i + 1).map(|n| n.text.as_str());
    let n2 = toks.get(i + 2).map(|n| n.text.as_str());
    let plain_assign = n1 == Some("=") && n2 != Some("=") && n2 != Some(">");
    let compound = matches!(n1, Some("+" | "-" | "*")) && n2 == Some("=");
    if plain_assign || compound {
        frame.vars.insert(t.text.clone());
    }
}

/// The ident tokens of the first argument of a call whose `(` sits at
/// `open - 1` — i.e. scanning from `open` to the first depth-0 `,`/`)`.
fn first_arg_idents(toks: &[Token], open: usize) -> Vec<String> {
    let mut depth = 0i32;
    let mut k = open;
    let mut out = Vec::new();
    while k < toks.len() {
        match toks[k].text.as_str() {
            "(" | "[" | "{" => depth += 1,
            ")" | "]" | "}" => {
                if depth == 0 {
                    break;
                }
                depth -= 1;
            }
            "," if depth == 0 => break,
            t if is_ident_text(t) => out.push(t.to_string()),
            _ => {}
        }
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::shard_map;

    fn file(path: &str, class: FileClass, src: &str) -> SourceFile {
        SourceFile { path: PathBuf::from(path), class, module: None, lexed: lex(src) }
    }

    fn mfile(path: &str, module: &str, src: &str) -> SourceFile {
        SourceFile {
            path: PathBuf::from(path),
            class: FileClass { sim: true, stats_defs: true, ..FileClass::default() },
            module: Some(module.to_string()),
            lexed: lex(src),
        }
    }

    fn sim() -> FileClass {
        FileClass { sim: true, stats_defs: true, ..FileClass::default() }
    }

    fn map(src: &str) -> ShardMap {
        shard_map::parse(Path::new("shard_map.toml"), src).expect("test map parses")
    }

    #[test]
    fn l1_fires_only_in_sim_modules() {
        let src = "use std::collections::HashMap;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unordered_container");
        assert_eq!(v[0].line, 1);
        let v = run(&[file("xtask/src/x.rs", FileClass::default(), src)], None);
        assert!(v.is_empty());
    }

    #[test]
    fn l2_fires_everywhere_except_hostclock() {
        let src = "let t0 = std::time::Instant::now();\n";
        let v = run(&[file("src/runtime/executor.rs", FileClass::default(), src)], None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "wall_clock");
        let hc = FileClass { hostclock: true, ..FileClass::default() };
        assert!(run(&[file("src/hostclock.rs", hc, src)], None).is_empty());
    }

    #[test]
    fn l2_env_reads_but_not_args_or_macro() {
        let v = run(&[file("a.rs", FileClass::default(), "std::env::var(\"X\");\n")], None);
        assert_eq!(v.len(), 1);
        let v = run(
            &[file(
                "a.rs",
                FileClass::default(),
                "std::env::args().skip(1);\nlet d = env!(\"CARGO_MANIFEST_DIR\");\n",
            )],
            None,
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l2_matches_exact_idents_only() {
        let src = "struct InstantTarget; fn f() {}\n";
        let v = run(&[file("a.rs", FileClass::default(), src)], None);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l3_manual_ord_and_float_heaps() {
        let src = "impl Ord for Key { }\nimpl<T> PartialOrd for K2<T> { }\n";
        let v = run(&[file("src/simcore/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|v| v.lint == "raw_event_key"));
        let src = "let h: BinaryHeap<(f64, u64)>;\n";
        let v = run(&[file("src/simcore/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1);
        // Derived ordering is fine.
        let v = run(
            &[file(
                "src/simcore/x.rs",
                sim(),
                "#[derive(PartialOrd, Ord)]\nstruct EventKey(u64, u64);\n",
            )],
            None,
        );
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l4_requires_an_audited_reference() {
        let def = file("src/faas/x.rs", sim(), "pub struct FooStats { pub n: u64 }\n");
        let v = run(&[def], None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unaudited_stats");

        let def = file("src/faas/x.rs", sim(), "pub struct FooStats { pub n: u64 }\n");
        let test_file = file(
            "tests/conservation.rs",
            FileClass { audited: true, ..FileClass::default() },
            "fn t() { let s: FooStats = todo!(); }\n",
        );
        assert!(run(&[def, test_file], None).is_empty());
    }

    #[test]
    fn l4_snake_case_reference_counts() {
        let src = "pub struct FooStats { pub n: u64 }\n\
                   fn check_invariants(foo_stats: &FooStats2) { let _ = foo_stats; }\n";
        // The body of check_invariants mentions foo_stats → FooStats is
        // considered audited via its snake_case name.
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l5_cross_module_handle_requires_declaration() {
        let owner = mfile("src/faas/c.rs", "faas", "pub struct Cluster { pub n: u64 }\n");
        let holder = mfile(
            "src/faultplane/mod.rs",
            "faultplane",
            "fn inject(cluster: &Rc<RefCell<Cluster>>) { cluster.borrow_mut().n += 1; }\n",
        );
        let m = map("[modules]\nfaas = \"gateway\"\nfaultplane = \"control\"\n");
        let v = run(&[owner, holder], Some(&m));
        assert_eq!(v.len(), 1, "{v:#?}");
        assert_eq!(v[0].lint, "undeclared_shared_state");
        assert_eq!((v[0].file.to_str().unwrap(), v[0].line), ("src/faultplane/mod.rs", 1));
    }

    #[test]
    fn l5_declared_handle_is_clean_and_builtins_are_exempt() {
        let owner = mfile("src/faas/c.rs", "faas", "pub struct Cluster { pub n: u64 }\n");
        let holder = mfile(
            "src/faultplane/mod.rs",
            "faultplane",
            "fn inject(c: &Rc<RefCell<Cluster>>, log: Rc<RefCell<Vec<u64>>>) {}\n",
        );
        let m = map("[modules]\nfaas = \"gateway\"\nfaultplane = \"control\"\n\
                     [state.Cluster]\nowner = \"faas\"\ndomain = \"gateway\"\n");
        let v = run(&[owner, holder], Some(&m));
        assert!(v.is_empty(), "{v:#?}");
    }

    #[test]
    fn l5_owner_mismatch_and_stale_entries_point_at_the_map() {
        let owner = mfile("src/faas/c.rs", "faas", "pub struct Cluster { pub n: u64 }\n");
        let holder =
            mfile("src/workload/mod.rs", "workload", "fn go(c: Rc<RefCell<Cluster>>) {}\n");
        let m = map("[modules]\nfaas = \"gateway\"\nworkload = \"gateway\"\n\
                     [state.Cluster]\nowner = \"workload\"\ndomain = \"gateway\"\n\
                     [state.Ghost]\nowner = \"faas\"\ndomain = \"value\"\n");
        let v = run(&[owner, holder], Some(&m));
        let msgs: Vec<&str> = v.iter().map(|v| v.msg.as_str()).collect();
        assert_eq!(v.len(), 2, "{v:#?}");
        assert!(v.iter().all(|v| v.file == Path::new("shard_map.toml")));
        assert!(msgs.iter().any(|m| m.contains("defined in module `faas`")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("stale")), "{msgs:?}");
    }

    #[test]
    fn l6_per_worker_cross_mutation_is_flagged_but_netpath_is_the_seam() {
        let owner = mfile("src/junction/q.rs", "junction", "pub struct Queue { pub n: u64 }\n");
        let src = "fn steal(q: &Rc<RefCell<Queue>>) {\nq.borrow_mut().n -= 1;\n}\n";
        let thief = mfile("src/snapshot/mod.rs", "snapshot", src);
        let seam = mfile("src/netpath/mod.rs", "netpath", src);
        let m = map("[modules]\njunction = \"per_worker\"\nsnapshot = \"per_worker\"\n\
                     netpath = \"wire\"\n\
                     [state.Queue]\nowner = \"junction\"\ndomain = \"per_worker\"\n");
        let v = run(&[owner, thief, seam], Some(&m));
        assert_eq!(v.len(), 1, "{v:#?}");
        assert_eq!(v[0].lint, "cross_shard_mut");
        assert_eq!((v[0].file.to_str().unwrap(), v[0].line), ("src/snapshot/mod.rs", 2));
    }

    #[test]
    fn l7_loop_invariant_schedule_is_flagged() {
        let src = "fn storm(sim: &mut Sim, base: u64) {\nfor w in 0..4 {\n\
                   sim.at(base, move |s| poke(s, w));\n}\n}\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1, "{v:#?}");
        assert_eq!((v[0].lint, v[0].line), ("tie_break_sensitive", 3));
    }

    #[test]
    fn l7_loop_varying_timestamps_are_clean() {
        // Loop var in the time argument, an ident assigned in the body,
        // and a fresh per-iteration Sim are all per-iteration: no ties.
        let src = "fn f(sim: &mut Sim) {\nfor w in 0..4 {\nsim.at(100 * w, go);\n}\n\
                   for _ in 0..4 {\nt += 5;\nsim.at(t, go);\n}\n\
                   for kind in BOTH {\nlet mut sim = Sim::with_engine(kind);\nsim.at(7, go);\n}\n}\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert!(v.is_empty(), "{v:#?}");
    }

    #[test]
    fn l7_after_zero_needs_a_rationale() {
        let src = "fn kick(sim: &mut Sim) {\nsim.after(0, drain);\n}\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1, "{v:#?}");
        assert_eq!((v[0].lint, v[0].line), ("tie_break_sensitive", 2));
        // Non-zero delays are not immediate.
        let src = "fn kick(sim: &mut Sim) {\nsim.after(10, drain);\n}\n";
        assert!(run(&[file("src/faas/x.rs", sim(), src)], None).is_empty());
    }

    #[test]
    fn l7_rationale_comments_excuse_within_three_lines() {
        let src = "fn kick(sim: &mut Sim) {\n// tie-break: drain order is load-bearing here\n\
                   sim.after(0, drain);\nfor w in 0..4 {\n\
                   // tie-break: grants race on purpose\nsim.at(9, go);\n}\n}\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert!(v.is_empty(), "{v:#?}");
        // A rationale more than three lines above the call is stale prose.
        let src = "fn kick(sim: &mut Sim) {\n// tie-break: too far away\n\nlet a = 1;\n\
                   let b = 2;\nsim.after(0, drain);\n}\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1, "{v:#?}");
    }

    #[test]
    fn allows_suppress_and_must_be_used() {
        let src = "// detlint:allow(unordered_container, ordered before output)\n\
                   use std::collections::HashMap;\n";
        assert!(run(&[file("src/faas/x.rs", sim(), src)], None).is_empty());

        let src = "// detlint:allow(unordered_container, stale)\nlet x = 1;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unused_allow");

        let src = "// detlint:allow(no_such_lint, whatever)\nlet x = 1;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "bad_allow");
    }

    #[test]
    fn an_allow_used_by_any_pass_is_not_stale() {
        // One allow absorbing an L5 hit (a graph-pass lint) must not be
        // reported unused by the suppression sweep — the regression the
        // unified pass exists to prevent — and map-file violations
        // survive even though the map has no lexed source to suppress
        // them with.
        let holder = mfile(
            "src/workload/mod.rs",
            "workload",
            "// detlint:allow(undeclared_shared_state, staged migration)\n\
             fn go(c: Rc<RefCell<Phantom>>) {}\n",
        );
        let m = map("[modules]\nworkload = \"gateway\"\n\
                     [state.Gone]\nowner = \"workload\"\ndomain = \"value\"\n");
        let v = run(&[holder], Some(&m));
        assert_eq!(v.len(), 1, "{v:#?}");
        assert_eq!(v[0].file, Path::new("shard_map.toml"));
        assert!(v[0].msg.contains("stale"), "{}", v[0].msg);
    }

    #[test]
    fn same_line_duplicates_collapse() {
        let src = "let m: HashMap<u32, u32> = HashMap::new();\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)], None);
        assert_eq!(v.len(), 1);
    }
}
