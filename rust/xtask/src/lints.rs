//! The detlint rules: four determinism / conservation lints over the
//! token streams produced by `lexer`, plus the `detlint:allow`
//! suppression protocol.
//!
//! - `unordered_container` (L1): no `HashMap` / `HashSet` in simulation
//!   modules — iteration order is randomized per process, so any order
//!   that reaches simulation state or output breaks same-seed
//!   byte-identical runs.
//! - `wall_clock` (L2): no `Instant` / `SystemTime` / `thread_rng` /
//!   environment reads outside the `hostclock` seam — the virtual
//!   timeline must never observe the host.
//! - `raw_event_key` (L3): event ordering must go through the derived
//!   `(time, seq)` `EventKey` — hand-written `Ord` impls and float-keyed
//!   heaps in simulation modules are flagged.
//! - `unaudited_stats` (L4): every `pub struct *Stats` must be named by
//!   at least one conservation test or `check_invariants` / `audit` body,
//!   so a counter can't drift without a test noticing.

use std::collections::BTreeSet;
use std::path::PathBuf;

use crate::lexer::{Lexed, Token};

pub const LINT_NAMES: [&str; 4] =
    ["unordered_container", "wall_clock", "raw_event_key", "unaudited_stats"];

/// How a file participates in the analysis; decided by `scan` from its
/// path (repo layout) or forced by fixture mode.
#[derive(Debug, Clone, Copy, Default)]
pub struct FileClass {
    /// Simulation module: L1 and L3 apply.
    pub sim: bool,
    /// The one allowlisted host seam (`src/hostclock.rs`): L2 exempt.
    pub hostclock: bool,
    /// `pub struct *Stats` definitions here must be audited (L4).
    pub stats_defs: bool,
    /// The whole file counts as audited context for L4 (tests, benches).
    pub audited: bool,
}

/// One lexed source file ready for analysis.
#[derive(Debug)]
pub struct SourceFile {
    /// Path as reported in diagnostics (relative to the crate root).
    pub path: PathBuf,
    pub class: FileClass,
    pub lexed: Lexed,
}

#[derive(Debug, Clone)]
pub struct Violation {
    pub file: PathBuf,
    pub line: u32,
    pub lint: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file.display(), self.line, self.lint, self.msg)
    }
}

/// Run every lint over `files` and apply suppressions. Returned
/// violations are sorted by (file, line, lint) and deduplicated per line
/// so one `HashMap<K, V> = HashMap::new()` line reports once.
pub fn run(files: &[SourceFile]) -> Vec<Violation> {
    let mut raw: Vec<Violation> = Vec::new();
    for sf in files {
        lint_unordered_container(sf, &mut raw);
        lint_wall_clock(sf, &mut raw);
        lint_raw_event_key(sf, &mut raw);
    }
    lint_unaudited_stats(files, &mut raw);

    let mut out: Vec<Violation> = Vec::new();
    let mut seen: BTreeSet<(PathBuf, u32, &'static str)> = BTreeSet::new();
    for sf in files {
        // An allow suppresses a violation on its own line or on the line
        // directly below it (comment-above style). Unused allows are
        // violations themselves: a stale suppression is a trap.
        let mut used = vec![false; sf.lexed.allows.len()];
        for v in raw.iter().filter(|v| v.file == sf.path) {
            let mut suppressed = false;
            for (ai, a) in sf.lexed.allows.iter().enumerate() {
                if a.lint == v.lint && (a.line == v.line || a.line + 1 == v.line) {
                    used[ai] = true;
                    suppressed = true;
                }
            }
            if !suppressed && seen.insert((v.file.clone(), v.line, v.lint)) {
                out.push(v.clone());
            }
        }
        for (ai, a) in sf.lexed.allows.iter().enumerate() {
            if !LINT_NAMES.contains(&a.lint.as_str()) {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: a.line,
                    lint: "bad_allow",
                    msg: format!("unknown lint {:?} in detlint:allow", a.lint),
                });
            } else if !used[ai] {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: a.line,
                    lint: "unused_allow",
                    msg: format!("detlint:allow({}) suppresses nothing here", a.lint),
                });
            }
        }
        for (line, msg) in &sf.lexed.bad_allows {
            out.push(Violation {
                file: sf.path.clone(),
                line: *line,
                lint: "bad_allow",
                msg: msg.clone(),
            });
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));
    out
}

/// L1: randomized-order containers in simulation modules.
fn lint_unordered_container(sf: &SourceFile, out: &mut Vec<Violation>) {
    if !sf.class.sim {
        return;
    }
    for t in &sf.lexed.tokens {
        if t.text == "HashMap" || t.text == "HashSet" {
            out.push(Violation {
                file: sf.path.clone(),
                line: t.line,
                lint: "unordered_container",
                msg: format!(
                    "{} in a simulation module: iteration order is per-process random and \
                     breaks same-seed determinism; use BTreeMap/BTreeSet or an indexed Vec",
                    t.text
                ),
            });
        }
    }
}

/// L2: host clock / entropy / environment reads outside `hostclock`.
fn lint_wall_clock(sf: &SourceFile, out: &mut Vec<Violation>) {
    if sf.class.hostclock {
        return;
    }
    let toks = &sf.lexed.tokens;
    let mut push = |line: u32, what: &str| {
        out.push(Violation {
            file: sf.path.clone(),
            line,
            lint: "wall_clock",
            msg: format!(
                "{what} outside the hostclock seam: the virtual timeline must not observe \
                 the host; route through crate::hostclock (bench wall-clock reporting only)"
            ),
        });
    };
    for (i, t) in toks.iter().enumerate() {
        match t.text.as_str() {
            "Instant" => push(t.line, "std::time::Instant"),
            "SystemTime" => push(t.line, "std::time::SystemTime"),
            "thread_rng" => push(t.line, "thread_rng (nondeterministic entropy)"),
            "rand" if toks.get(i + 1).map(|n| n.text.as_str()) == Some("::") => {
                push(t.line, "the rand crate (nondeterministic entropy)");
            }
            "env" => {
                // std::env::var / var_os / vars / vars_os are host state;
                // env::args (CLI input) and the compile-time env! macro
                // are fine.
                let nx = toks.get(i + 1).map(|n| n.text.as_str());
                let nx2 = toks.get(i + 2).map(|n| n.text.as_str());
                if nx == Some("::")
                    && matches!(nx2, Some("var" | "var_os" | "vars" | "vars_os"))
                {
                    push(t.line, "an environment read");
                }
            }
            _ => {}
        }
    }
}

/// L3: hand-rolled ordering in simulation modules — `impl Ord /
/// PartialOrd for …` and float-keyed `BinaryHeap`s. The derived
/// `(time, seq)` `EventKey` is the only sanctioned event order.
fn lint_raw_event_key(sf: &SourceFile, out: &mut Vec<Violation>) {
    if !sf.class.sim {
        return;
    }
    let toks = &sf.lexed.tokens;
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text == "impl" {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text.as_str()) == Some("<") {
                j = skip_angle_brackets(toks, j);
            }
            if let Some(t) = toks.get(j) {
                if (t.text == "Ord" || t.text == "PartialOrd")
                    && toks.get(j + 1).map(|n| n.text.as_str()) == Some("for")
                {
                    out.push(Violation {
                        file: sf.path.clone(),
                        line: t.line,
                        lint: "raw_event_key",
                        msg: format!(
                            "hand-written {} impl in a simulation module: event ordering must \
                             use the derived (time, seq) EventKey, not ad-hoc comparisons",
                            t.text
                        ),
                    });
                }
            }
        } else if toks[i].text == "BinaryHeap"
            && toks.get(i + 1).map(|t| t.text.as_str()) == Some("<")
        {
            let end = skip_angle_brackets(toks, i + 1);
            if toks[i + 1..end.min(toks.len())]
                .iter()
                .any(|t| t.text == "f64" || t.text == "f32")
            {
                out.push(Violation {
                    file: sf.path.clone(),
                    line: toks[i].line,
                    lint: "raw_event_key",
                    msg: "float-keyed BinaryHeap in a simulation module: floats have no total \
                          order and ties are seed-visible; key events by the derived (time, seq) \
                          EventKey"
                        .to_string(),
                });
            }
        }
        i += 1;
    }
}

/// Skip a balanced `<…>` region starting at the `<` at index `open`;
/// returns the index just past the matching `>`.
fn skip_angle_brackets(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "<" => depth += 1,
            ">" => {
                depth -= 1;
                if depth <= 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    k
}

/// L4: every `pub struct *Stats` definition must be referenced — by type
/// name or snake_case name — inside audited context: a test file, a
/// bench, a `#[cfg(test)]` region, or the body of a `check_invariants` /
/// `audit` / `audit_into` / `audit_tree` fn.
fn lint_unaudited_stats(files: &[SourceFile], out: &mut Vec<Violation>) {
    let mut defs: Vec<(PathBuf, u32, String)> = Vec::new();
    for sf in files {
        if !sf.class.stats_defs {
            continue;
        }
        let toks = &sf.lexed.tokens;
        for i in 0..toks.len() {
            if toks[i].text == "pub"
                && toks.get(i + 1).map(|t| t.text.as_str()) == Some("struct")
            {
                if let Some(name) = toks.get(i + 2) {
                    if name.text.ends_with("Stats") {
                        defs.push((sf.path.clone(), name.line, name.text.clone()));
                    }
                }
            }
        }
    }
    if defs.is_empty() {
        return;
    }

    let mut audited: BTreeSet<String> = BTreeSet::new();
    for sf in files {
        collect_audited(sf, &mut audited);
    }

    for (file, line, name) in defs {
        let snake = snake_case(&name);
        if !audited.contains(&name) && !audited.contains(&snake) {
            out.push(Violation {
                file,
                line,
                lint: "unaudited_stats",
                msg: format!(
                    "pub struct {name} is not referenced by any conservation test or \
                     check_invariants/audit impl; counters that nothing checks drift silently"
                ),
            });
        }
    }
}

/// Gather the audited-context token set from one file.
fn collect_audited(sf: &SourceFile, audited: &mut BTreeSet<String>) {
    let toks = &sf.lexed.tokens;
    if sf.class.audited {
        for t in toks {
            audited.insert(t.text.clone());
        }
        return;
    }
    // #[cfg(test)] to end of file. An approximation of module scope, but
    // in this crate the test module is always the tail of the file, and
    // widening the audited region only ever errs toward acceptance.
    let mut i = 0;
    while i + 4 < toks.len() {
        if toks[i].text == "#"
            && toks[i + 1].text == "["
            && toks[i + 2].text == "cfg"
            && toks[i + 3].text == "("
            && toks[i + 4].text == "test"
        {
            for t in &toks[i..] {
                audited.insert(t.text.clone());
            }
            break;
        }
        i += 1;
    }
    // Bodies of invariant-auditing fns.
    let mut i = 0;
    while i + 1 < toks.len() {
        if toks[i].text == "fn"
            && matches!(
                toks[i + 1].text.as_str(),
                "check_invariants" | "audit" | "audit_into" | "audit_tree"
            )
        {
            let mut k = i + 2;
            while k < toks.len() && toks[k].text != "{" {
                k += 1;
            }
            let mut depth = 0i32;
            while k < toks.len() {
                match toks[k].text.as_str() {
                    "{" => depth += 1,
                    "}" => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    _ => {}
                }
                audited.insert(toks[k].text.clone());
                k += 1;
            }
            i = k;
        }
        i += 1;
    }
}

fn snake_case(name: &str) -> String {
    let mut s = String::new();
    for (i, c) in name.chars().enumerate() {
        if c.is_ascii_uppercase() {
            if i > 0 {
                s.push('_');
            }
            s.push(c.to_ascii_lowercase());
        } else {
            s.push(c);
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;

    fn file(path: &str, class: FileClass, src: &str) -> SourceFile {
        SourceFile { path: PathBuf::from(path), class, lexed: lex(src) }
    }

    fn sim() -> FileClass {
        FileClass { sim: true, stats_defs: true, ..FileClass::default() }
    }

    #[test]
    fn l1_fires_only_in_sim_modules() {
        let src = "use std::collections::HashMap;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unordered_container");
        assert_eq!(v[0].line, 1);
        let v = run(&[file("xtask/src/x.rs", FileClass::default(), src)]);
        assert!(v.is_empty());
    }

    #[test]
    fn l2_fires_everywhere_except_hostclock() {
        let src = "let t0 = std::time::Instant::now();\n";
        let v = run(&[file("src/runtime/executor.rs", FileClass::default(), src)]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "wall_clock");
        let hc = FileClass { hostclock: true, ..FileClass::default() };
        assert!(run(&[file("src/hostclock.rs", hc, src)]).is_empty());
    }

    #[test]
    fn l2_env_reads_but_not_args_or_macro() {
        let v = run(&[file("a.rs", FileClass::default(), "std::env::var(\"X\");\n")]);
        assert_eq!(v.len(), 1);
        let v = run(&[file(
            "a.rs",
            FileClass::default(),
            "std::env::args().skip(1);\nlet d = env!(\"CARGO_MANIFEST_DIR\");\n",
        )]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l2_matches_exact_idents_only() {
        let v = run(&[file("a.rs", FileClass::default(), "struct InstantTarget; fn f() {}\n")]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l3_manual_ord_and_float_heaps() {
        let src = "impl Ord for Key { }\nimpl<T> PartialOrd for K2<T> { }\n";
        let v = run(&[file("src/simcore/x.rs", sim(), src)]);
        assert_eq!(v.len(), 2);
        assert!(v.iter().all(|v| v.lint == "raw_event_key"));
        let v = run(&[file("src/simcore/x.rs", sim(), "let h: BinaryHeap<(f64, u64)>;\n")]);
        assert_eq!(v.len(), 1);
        // Derived ordering is fine.
        let v = run(&[file(
            "src/simcore/x.rs",
            sim(),
            "#[derive(PartialOrd, Ord)]\nstruct EventKey(u64, u64);\n",
        )]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn l4_requires_an_audited_reference() {
        let def = file("src/faas/x.rs", sim(), "pub struct FooStats { pub n: u64 }\n");
        let v = run(&[def]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unaudited_stats");

        let def = file("src/faas/x.rs", sim(), "pub struct FooStats { pub n: u64 }\n");
        let test_file = file(
            "tests/conservation.rs",
            FileClass { audited: true, ..FileClass::default() },
            "fn t() { let s: FooStats = todo!(); }\n",
        );
        assert!(run(&[def, test_file]).is_empty());
    }

    #[test]
    fn l4_snake_case_reference_counts() {
        let src = "pub struct FooStats { pub n: u64 }\n\
                   fn check_invariants(foo_stats: &FooStats2) { let _ = foo_stats; }\n";
        // The body of check_invariants mentions foo_stats → FooStats is
        // considered audited via its snake_case name.
        let v = run(&[file("src/faas/x.rs", sim(), src)]);
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn allows_suppress_and_must_be_used() {
        let src = "// detlint:allow(unordered_container, ordered before output)\n\
                   use std::collections::HashMap;\n";
        assert!(run(&[file("src/faas/x.rs", sim(), src)]).is_empty());

        let src = "// detlint:allow(unordered_container, stale)\nlet x = 1;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "unused_allow");

        let src = "// detlint:allow(no_such_lint, whatever)\nlet x = 1;\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)]);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].lint, "bad_allow");
    }

    #[test]
    fn same_line_duplicates_collapse() {
        let src = "let m: HashMap<u32, u32> = HashMap::new();\n";
        let v = run(&[file("src/faas/x.rs", sim(), src)]);
        assert_eq!(v.len(), 1);
    }
}
