//! A minimal Rust lexer for detlint.
//!
//! Just enough of the language to walk a source file as a stream of
//! identifier / punctuation tokens with line numbers, with comments and
//! string / char literals stripped so a lint never fires on prose, and
//! with `// detlint:allow(<lint>, reason)` suppression comments collected
//! as structured directives.
//!
//! Deliberately dependency-free: the offline environment that builds this
//! repo has no crates.io registry, so a `syn`-based AST pass is not an
//! option. The lint rules in `lints.rs` are designed to need only
//! token-level matching plus balanced-bracket skips, which this lexer
//! provides. Handled here: line and (nested) block comments, string
//! literals with escapes, raw and byte strings (`r"…"`, `r#"…"#`,
//! `b"…"`, `br"…"`), byte chars, char-literal vs lifetime
//! disambiguation, raw identifiers (`r#fn`), and `::` as a joined token.

/// One surviving token: an identifier / keyword / number, a `::`, or a
/// single punctuation character.
#[derive(Debug, Clone)]
pub struct Token {
    pub text: String,
    pub line: u32,
}

/// A well-formed `// detlint:allow(<lint>, reason)` directive.
#[derive(Debug, Clone)]
pub struct Allow {
    pub lint: String,
    pub line: u32,
}

/// Result of lexing one file.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Token>,
    pub allows: Vec<Allow>,
    /// Malformed suppression attempts: `(line, message)`. Always errors —
    /// a suppression that silently fails to parse would hide violations.
    pub bad_allows: Vec<(u32, String)>,
    /// Lines whose comment text contains a `tie-break:` ordering
    /// rationale — the L7 (`tie_break_sensitive`) suppression marker.
    /// Collected from every comment flavour (doc comments included: a
    /// rationale is prose, not a directive).
    pub rationales: Vec<u32>,
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Lex `src` into tokens + suppression directives. Never fails: unknown
/// bytes become single-character punctuation tokens, and an unterminated
/// literal simply consumes to end of file (rustc will reject the file
/// anyway; detlint only needs to not panic or mis-tokenize what follows).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0;
    let mut line: u32 = 1;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
        } else if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i;
            while i < chars.len() && chars[i] != '\n' {
                i += 1;
            }
            let comment: String = chars[start..i].iter().collect();
            scan_rationale(&comment, line, &mut out);
            if !is_doc_comment(&comment) {
                scan_allow(&comment, line, &mut out);
            }
        } else if c == '/' && chars.get(i + 1) == Some(&'*') {
            let (start, start_line) = (i, line);
            i += 2;
            let mut depth = 1u32;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            let comment: String = chars[start..i.min(chars.len())].iter().collect();
            scan_rationale(&comment, start_line, &mut out);
            if !is_doc_comment(&comment) {
                scan_allow(&comment, start_line, &mut out);
            }
        } else if c == '"' {
            i = skip_string(&chars, i, &mut line);
        } else if c == '\'' {
            i = skip_char_or_lifetime(&chars, i);
        } else if is_ident_start(c) {
            i = lex_word(&chars, i, &mut line, &mut out);
        } else if c.is_ascii_digit() {
            // Numbers are consumed loosely (digits, letters, `_`, `.`) so
            // suffixed literals like `1.0f64` never shed an `f64` ident.
            let start = i;
            while i < chars.len() && (is_ident_continue(chars[i]) || chars[i] == '.') {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            out.tokens.push(Token { text, line });
        } else if c == ':' && chars.get(i + 1) == Some(&':') {
            out.tokens.push(Token { text: "::".to_string(), line });
            i += 2;
        } else if c.is_whitespace() {
            i += 1;
        } else {
            out.tokens.push(Token { text: c.to_string(), line });
            i += 1;
        }
    }
    out
}

/// Lex something that starts like an identifier: a plain ident, a raw
/// identifier (`r#fn`), or a raw / byte string or byte-char literal
/// (`r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'x'`). Returns the index just
/// past whatever was consumed.
fn lex_word(chars: &[char], i: usize, line: &mut u32, out: &mut Lexed) -> usize {
    let c = chars[i];
    if c == 'r' || c == 'b' {
        // Candidate literal prefix: `r`, `b`, or `br`, then `#`s, then `"`.
        let mut j = i + 1;
        let mut raw = c == 'r';
        if c == 'b' && chars.get(j) == Some(&'r') {
            raw = true;
            j += 1;
        }
        let mut hashes = 0usize;
        while chars.get(j + hashes) == Some(&'#') {
            hashes += 1;
        }
        if chars.get(j + hashes) == Some(&'"') {
            if raw {
                return skip_raw_string(chars, j + hashes + 1, hashes, line);
            }
            if hashes == 0 && j == i + 1 {
                // b"…" — escapes behave like a normal string.
                return skip_string(chars, j, line);
            }
        }
        if c == 'b' && j == i + 1 && hashes == 0 && chars.get(j) == Some(&'\'') {
            return skip_char_or_lifetime(chars, j);
        }
        if c == 'r' && hashes >= 1 && chars.get(j + hashes).copied().is_some_and(is_ident_start) {
            // Raw identifier r#ident: emit the bare ident.
            let start = j + hashes;
            let mut k = start;
            while k < chars.len() && is_ident_continue(chars[k]) {
                k += 1;
            }
            let text: String = chars[start..k].iter().collect();
            out.tokens.push(Token { text, line: *line });
            return k;
        }
    }
    let start = i;
    let mut k = i;
    while k < chars.len() && is_ident_continue(chars[k]) {
        k += 1;
    }
    let text: String = chars[start..k].iter().collect();
    out.tokens.push(Token { text, line: *line });
    k
}

/// Skip a `"…"` literal with escapes; `i` points at the opening quote.
fn skip_string(chars: &[char], i: usize, line: &mut u32) -> usize {
    let mut k = i + 1;
    while k < chars.len() {
        match chars[k] {
            '\\' => {
                // A `\`-newline continuation still ends a source line;
                // skipping it blind would drift every later diagnostic.
                if chars.get(k + 1) == Some(&'\n') {
                    *line += 1;
                }
                k += 2;
            }
            '"' => return k + 1,
            '\n' => {
                *line += 1;
                k += 1;
            }
            _ => k += 1,
        }
    }
    k
}

/// Skip a raw string body; `start` points just past the opening quote,
/// and the literal closes at `"` followed by `hashes` `#`s.
fn skip_raw_string(chars: &[char], start: usize, hashes: usize, line: &mut u32) -> usize {
    let mut k = start;
    while k < chars.len() {
        if chars[k] == '\n' {
            *line += 1;
        } else if chars[k] == '"' {
            let mut h = 0;
            while h < hashes && chars.get(k + 1 + h) == Some(&'#') {
                h += 1;
            }
            if h == hashes {
                return k + 1 + hashes;
            }
        }
        k += 1;
    }
    k
}

/// `i` points at a `'`: either a char literal (skipped) or a lifetime
/// (consumed without emitting — lints never key on lifetimes).
fn skip_char_or_lifetime(chars: &[char], i: usize) -> usize {
    match chars.get(i + 1) {
        Some('\\') => {
            // Escape: consume quote + backslash + escaped char, then scan
            // to the closing quote (covers \u{…}).
            let mut k = i + 3;
            while k < chars.len() && chars[k] != '\'' {
                k += 1;
            }
            k + 1
        }
        Some(&c) if is_ident_continue(c) => {
            if chars.get(i + 2) == Some(&'\'') {
                i + 3 // 'x'
            } else {
                // Lifetime: consume the ident and move on.
                let mut k = i + 2;
                while k < chars.len() && is_ident_continue(chars[k]) {
                    k += 1;
                }
                k
            }
        }
        Some(_) => {
            // Punctuation char literal like '(' — defensively scan for
            // the closing quote.
            let mut k = i + 2;
            while k < chars.len() && chars[k] != '\'' {
                k += 1;
            }
            k + 1
        }
        None => i + 1,
    }
}

/// Doc comments are rendered prose, never directives: they may quote the
/// suppression grammar without tripping the malformed-allow check (this
/// very crate's docs do). A real suppression must be a plain comment.
fn is_doc_comment(comment: &str) -> bool {
    comment.starts_with("///")
        || comment.starts_with("//!")
        // `/**/` is an *empty plain* comment, not a doc comment.
        || (comment.starts_with("/**") && !comment.starts_with("/**/"))
        || comment.starts_with("/*!")
}

/// Record the line of every `tie-break:` ordering rationale inside one
/// comment (block comments may span lines; each matching line counts).
fn scan_rationale(comment: &str, start_line: u32, out: &mut Lexed) {
    for (off, l) in comment.lines().enumerate() {
        if l.contains("tie-break:") {
            out.rationales.push(start_line + off as u32);
        }
    }
}

/// Parse every `detlint:allow(lint, reason)` occurrence inside one
/// comment. The lint name must be a known snake_case word and the reason
/// must be nonempty — both checked later against the lint registry; here
/// we only enforce shape.
fn scan_allow(comment: &str, line: u32, out: &mut Lexed) {
    let needle = "detlint:allow";
    let mut rest = comment;
    while let Some(p) = rest.find(needle) {
        let after = &rest[p + needle.len()..];
        let Some(body) = after.strip_prefix('(') else {
            out.bad_allows
                .push((line, "detlint:allow must be written detlint:allow(lint, reason)".into()));
            rest = after;
            continue;
        };
        let Some(close) = body.find(')') else {
            out.bad_allows.push((line, "unclosed detlint:allow(".into()));
            return;
        };
        match body[..close].split_once(',') {
            Some((lint, reason)) => {
                let lint = lint.trim();
                let reason = reason.trim().trim_matches('"').trim();
                if lint.is_empty() || !lint.chars().all(|c| c.is_ascii_lowercase() || c == '_') {
                    out.bad_allows
                        .push((line, format!("bad lint name {lint:?} in detlint:allow")));
                } else if reason.is_empty() {
                    out.bad_allows
                        .push((line, format!("detlint:allow({lint}, …) requires a reason")));
                } else {
                    out.allows.push(Allow { lint: lint.to_string(), line });
                }
            }
            None => {
                out.bad_allows
                    .push((line, "detlint:allow(lint) is missing the required reason".into()));
            }
        }
        rest = &body[close..];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).tokens.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let src = "let x = \"Instant::now()\"; // Instant in prose\n/* HashMap */ let y = 1;";
        let toks = texts(src);
        assert!(!toks.iter().any(|t| t == "Instant"));
        assert!(!toks.iter().any(|t| t == "HashMap"));
        assert!(toks.iter().any(|t| t == "y"));
    }

    #[test]
    fn raw_and_byte_strings_are_stripped() {
        let src = "let a = r#\"SystemTime \" quoted\"#; let b = b\"thread_rng\"; let c = br\"x\";";
        let toks = texts(src);
        assert!(!toks.iter().any(|t| t == "SystemTime" || t == "thread_rng"));
        assert!(toks.iter().any(|t| t == "c"));
    }

    #[test]
    fn lifetimes_and_char_literals() {
        let toks = texts("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert!(toks.iter().any(|t| t == "str"));
        assert!(toks.iter().any(|t| t == "char"));
        let toks = texts(r"let q = '\''; let z = 3;");
        assert!(toks.iter().any(|t| t == "z"));
    }

    #[test]
    fn numeric_suffixes_do_not_shed_idents() {
        let toks = texts("let t = 1.0f64; let u = 0x10u64;");
        assert!(!toks.iter().any(|t| t == "f64"));
        assert!(toks.iter().any(|t| t == "1.0f64"));
    }

    #[test]
    fn double_colon_is_one_token() {
        let toks = texts("std::time::Instant::now()");
        assert_eq!(toks, vec!["std", "::", "time", "::", "Instant", "::", "now", "(", ")"]);
    }

    #[test]
    fn nested_block_comments() {
        let toks = texts("/* outer /* inner */ still comment */ let z = 1;");
        assert_eq!(toks[0], "let");
    }

    #[test]
    fn allow_directives_parse() {
        let l = lex("// detlint:allow(wall_clock, bench wall-clock reporting)\nlet t = 1;");
        assert_eq!(l.allows.len(), 1);
        assert_eq!(l.allows[0].lint, "wall_clock");
        assert_eq!(l.allows[0].line, 1);
        assert!(l.bad_allows.is_empty());
    }

    #[test]
    fn allow_without_reason_is_malformed() {
        let l = lex("// detlint:allow(wall_clock)\nlet t = 1;");
        assert!(l.allows.is_empty());
        assert_eq!(l.bad_allows.len(), 1);
        let l = lex("// detlint:allow(wall_clock,   )\nlet t = 1;");
        assert!(l.allows.is_empty());
        assert_eq!(l.bad_allows.len(), 1);
    }

    #[test]
    fn string_escapes_do_not_drift_line_numbers() {
        // A `\`-newline continuation inside a string literal must still
        // count the newline, or every later diagnostic points one line
        // high (regression: the escape arm skipped it blind).
        let l = lex("let s = \"a\\\nb\";\nlet after = 1;");
        let tok = l.tokens.iter().find(|t| t.text == "after").unwrap();
        assert_eq!(tok.line, 3);
    }

    #[test]
    fn raw_byte_strings_with_hashes_do_not_leak_tokens() {
        let toks = texts("let a = br#\"HashMap \" Instant\"#; let tail = 1;");
        assert!(!toks.iter().any(|t| t == "HashMap" || t == "Instant"));
        assert!(toks.iter().any(|t| t == "tail"), "lexer must resync after br#…#");
    }

    #[test]
    fn tie_break_rationales_are_collected_with_lines() {
        let l = lex("// tie-break: deliberate fan-out\nlet a = 1;\n/* tie-break: here too */\n");
        assert_eq!(l.rationales, vec![1, 3]);
        // Multi-line block comments attribute the rationale to its line.
        let l = lex("/* preamble\n   tie-break: in a block\n*/\nlet x = 1;");
        assert_eq!(l.rationales, vec![2]);
        // Doc comments count: a rationale is prose, not a directive.
        let l = lex("/// tie-break: documented ordering\nlet x = 1;");
        assert_eq!(l.rationales, vec![1]);
        assert!(l.bad_allows.is_empty());
    }

    #[test]
    fn empty_block_comment_is_not_a_doc_comment() {
        // `/**/` must classify as a plain comment (doc comments skip the
        // allow scanner; an empty comment has nothing to scan either way,
        // but the classifier should not lie).
        assert!(!is_doc_comment("/**/"));
        assert!(is_doc_comment("/** real doc */"));
    }

    #[test]
    fn raw_identifiers() {
        let toks = texts("let r#fn = 1;");
        assert!(toks.iter().any(|t| t == "fn"));
    }

    #[test]
    fn doc_comments_may_quote_the_grammar() {
        let l = lex("/// write `// detlint:allow(<lint>, reason)` above the line\nlet t = 1;");
        assert!(l.allows.is_empty());
        assert!(l.bad_allows.is_empty(), "{:?}", l.bad_allows);
        let l = lex("//! plus the `detlint:allow` suppression protocol\nlet t = 1;");
        assert!(l.bad_allows.is_empty(), "{:?}", l.bad_allows);
    }
}
