//! `cargo xtask <cmd>` — see the alias in `rust/.cargo/config.toml`.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::{graph, lints, scan};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("detlint") => detlint(&args[1..]),
        Some("schedcheck") => schedcheck(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask <detlint [--path DIR] [--graph] | schedcheck [args..]>");
            eprintln!();
            eprintln!("  detlint          lint the repo for determinism/shard-safety hazards");
            eprintln!("  detlint --path D lint every .rs under D as if it were a sim module");
            eprintln!("  detlint --graph  also dump the module state-access graph");
            eprintln!("  schedcheck ..    build + run the tie-break schedule explorer (E17)");
            ExitCode::from(2)
        }
    }
}

fn detlint(args: &[String]) -> ExitCode {
    let mut path: Option<PathBuf> = None;
    let mut dump_graph = false;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--path" => match it.next() {
                Some(p) => path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --path needs a directory");
                    return ExitCode::from(2);
                }
            },
            "--graph" => dump_graph = true,
            other => {
                eprintln!("detlint: unknown flag {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    let (files, map_path, map_required) = match &path {
        Some(dir) => (scan::collect_dir(dir), dir.join("shard_map.toml"), false),
        None => {
            let root = scan::crate_root();
            (scan::collect_repo(&root), scan::repo_shard_map(&root), true)
        }
    };
    let files = match files {
        Ok(f) => f,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };
    let map = match lints::load_map(&map_path) {
        Ok(m) => m,
        Err(errs) => {
            for v in &errs {
                println!("{v}");
            }
            eprintln!("detlint: shard map failed to parse ({} error(s))", errs.len());
            return ExitCode::FAILURE;
        }
    };
    if map.is_none() && map_required {
        eprintln!("detlint: missing {} (required for L5/L6)", map_path.display());
        return ExitCode::FAILURE;
    }
    if dump_graph {
        print!("{}", graph::StateGraph::build(&files).dump());
    }
    let violations = lints::run(&files, map.as_ref());
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("detlint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}

/// Build the release binary and forward to its `schedcheck` subcommand.
/// Kept as a shell-out so xtask stays dependency-free and the explorer
/// runs the exact binary CI byte-diffs.
fn schedcheck(args: &[String]) -> ExitCode {
    let status = std::process::Command::new(env!("CARGO"))
        .current_dir(scan::crate_root())
        .args(["run", "--release", "--package", "junctiond-repro", "--", "schedcheck"])
        .args(args)
        .status();
    match status {
        Ok(s) if s.success() => ExitCode::SUCCESS,
        Ok(s) => ExitCode::from(s.code().unwrap_or(1).clamp(0, 255) as u8),
        Err(e) => {
            eprintln!("schedcheck: failed to launch cargo: {e}");
            ExitCode::from(2)
        }
    }
}
