//! `cargo xtask <cmd>` — see the alias in `rust/.cargo/config.toml`.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::{lints, scan};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("detlint") => detlint(&args[1..]),
        _ => {
            eprintln!("usage: cargo xtask detlint [--path DIR]");
            eprintln!();
            eprintln!("  detlint          lint the repo for determinism/conservation hazards");
            eprintln!("  detlint --path D lint every .rs under D as if it were a sim module");
            ExitCode::from(2)
        }
    }
}

fn detlint(args: &[String]) -> ExitCode {
    let mut path: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--path" => match it.next() {
                Some(p) => path = Some(PathBuf::from(p)),
                None => {
                    eprintln!("detlint: --path needs a directory");
                    return ExitCode::from(2);
                }
            },
            other => {
                eprintln!("detlint: unknown flag {other:?}");
                return ExitCode::from(2);
            }
        }
    }
    let files = match &path {
        Some(dir) => scan::collect_dir(dir),
        None => scan::collect_repo(&scan::crate_root()),
    };
    let files = match files {
        Ok(f) => f,
        Err(e) => {
            eprintln!("detlint: {e}");
            return ExitCode::from(2);
        }
    };
    let violations = lints::run(&files);
    for v in &violations {
        println!("{v}");
    }
    if violations.is_empty() {
        println!("detlint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("detlint: {} violation(s)", violations.len());
        ExitCode::FAILURE
    }
}
