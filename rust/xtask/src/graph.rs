//! The module-level state-access graph behind detlint's shard-safety
//! lints (L5/L6).
//!
//! Built from the same token streams the other lints walk — no AST, no
//! crates — the graph records, per simulation module: which shared
//! types it *defines*, which `Rc<RefCell<T>>` handles it *holds* (a
//! binding annotation, struct field, fn param, or bare type position),
//! where it *mutates* through a held handle (`h.borrow_mut()`), where a
//! handle *escapes* by cloning (`Rc::clone(&h)` / `h.clone()`), and the
//! `&mut self` method surfaces of the types it implements. Only handles
//! with a *named*, non-builtin inner type participate in the shard
//! lints: `Rc<RefCell<Vec<_>>>` or a tuple gauge is closure-local
//! plumbing, not shard state; `Rc<RefCell<Cluster>>` is the real thing.
//!
//! The extraction is deliberately conservative in the same way the
//! lexer is: it only sees annotated handles (`x: Rc<RefCell<T>>`), so
//! an un-annotated `Rc::new(RefCell::new(..))` local never enters the
//! graph. That under-approximates — but every cross-module handle in
//! this crate crosses a fn/struct boundary, which forces the annotation
//! the graph keys on.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;

use crate::lexer::Token;
use crate::lints::SourceFile;

/// One held `Rc<RefCell<inner>>` handle.
#[derive(Debug, Clone)]
pub struct HandleRef {
    /// Binding / field / param name; `None` for a bare type position
    /// (return type, `impl Trait for Rc<RefCell<T>>`).
    pub binding: Option<String>,
    /// Inner type name, or `"(tuple)"` for an anonymous tuple.
    pub inner: String,
    pub file: PathBuf,
    pub line: u32,
}

/// One `handle.borrow_mut()` mutation through a held handle.
#[derive(Debug, Clone)]
pub struct Mutation {
    pub binding: String,
    /// Inner type of the handle the binding was declared with.
    pub inner: String,
    pub file: PathBuf,
    pub line: u32,
}

/// What one module constructs, holds, and mutates.
#[derive(Debug, Default)]
pub struct ModuleAccess {
    /// Types this module defines (`struct` / `enum`), with first def site.
    pub defines: BTreeMap<String, (PathBuf, u32)>,
    pub handles: Vec<HandleRef>,
    pub mutations: Vec<Mutation>,
    /// `Rc::clone(&h)` / `h.clone()` escape sites of held handles.
    pub escapes: Vec<(String, PathBuf, u32)>,
    /// `(type, method, line)` for every `fn m(&mut self, ..)` surface.
    pub mut_surfaces: Vec<(String, String, u32)>,
}

/// The whole graph: module name → accesses.
#[derive(Debug, Default)]
pub struct StateGraph {
    pub modules: BTreeMap<String, ModuleAccess>,
}

/// Container / std types whose `Rc<RefCell<..>>` wrapping is closure
/// plumbing rather than nameable shard state. Lowercase-initial names
/// (primitives) and tuples are excluded by the same test.
pub fn is_builtin(inner: &str) -> bool {
    matches!(
        inner,
        "(tuple)"
            | "Vec"
            | "VecDeque"
            | "BTreeMap"
            | "BTreeSet"
            | "Option"
            | "Box"
            | "String"
            | "Cell"
            | "RefCell"
    ) || !inner.chars().next().is_some_and(|c| c.is_ascii_uppercase())
}

impl StateGraph {
    /// Build the graph from every file that carries a module name (sim
    /// modules in repo mode; every file in fixture mode).
    pub fn build(files: &[SourceFile]) -> StateGraph {
        let mut g = StateGraph::default();
        for sf in files {
            let Some(module) = &sf.module else { continue };
            let acc = g.modules.entry(module.clone()).or_default();
            extract(sf, acc);
        }
        g
    }

    /// Module that defines `ty`, if any scanned module does.
    pub fn def_site(&self, ty: &str) -> Option<&str> {
        self.modules
            .iter()
            .find(|(_, acc)| acc.defines.contains_key(ty))
            .map(|(m, _)| m.as_str())
    }

    /// Human-readable dump for `cargo xtask detlint --graph`.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        for (m, acc) in &self.modules {
            s.push_str(&format!("module {m}\n"));
            for (ty, (f, l)) in &acc.defines {
                s.push_str(&format!("  defines  {ty}  ({}:{l})\n", f.display()));
            }
            for h in &acc.handles {
                let b = h.binding.as_deref().unwrap_or("<type position>");
                let at = format!("({}:{})", h.file.display(), h.line);
                s.push_str(&format!("  holds    Rc<RefCell<{}>> as {b}  {at}\n", h.inner));
            }
            for mu in &acc.mutations {
                let at = format!("({}:{})", mu.file.display(), mu.line);
                let b = &mu.binding;
                s.push_str(&format!("  mutates  {} via {b}.borrow_mut()  {at}\n", mu.inner));
            }
            for (b, f, l) in &acc.escapes {
                s.push_str(&format!("  escapes  {b} cloned  ({}:{l})\n", f.display()));
            }
            for (ty, method, l) in &acc.mut_surfaces {
                s.push_str(&format!("  &mut     {ty}::{method}  (line {l})\n"));
            }
        }
        s
    }
}

fn is_ident(t: &str) -> bool {
    t.chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Walk one file's tokens into `acc`.
fn extract(sf: &SourceFile, acc: &mut ModuleAccess) {
    let toks = &sf.lexed.tokens;
    // Pass 1: type definitions and handle declarations.
    let mut local: BTreeMap<String, String> = BTreeMap::new(); // binding → inner
    for i in 0..toks.len() {
        let t = &toks[i];
        if (t.text == "struct" || t.text == "enum") && i + 1 < toks.len() {
            let name = &toks[i + 1];
            if is_ident(&name.text) && name.text.chars().next().is_some_and(char::is_uppercase) {
                acc.defines
                    .entry(name.text.clone())
                    .or_insert_with(|| (sf.path.clone(), name.line));
            }
        }
        if t.text == "Rc" && toks.get(i + 1).is_some_and(|n| n.text == "<") {
            if let Some(inner) = refcell_inner(toks, i + 2) {
                let binding = binding_before(toks, i);
                if let Some(b) = &binding {
                    local.insert(b.clone(), inner.clone());
                }
                acc.handles.push(HandleRef {
                    binding,
                    inner,
                    file: sf.path.clone(),
                    line: t.line,
                });
            }
        }
    }
    // Pass 2: mutations and escapes through the handles pass 1 named.
    for i in 0..toks.len() {
        let t = &toks[i];
        if is_ident(&t.text)
            && toks.get(i + 1).is_some_and(|n| n.text == ".")
            && toks.get(i + 2).is_some_and(|n| n.text == "borrow_mut")
        {
            if let Some(inner) = local.get(&t.text) {
                acc.mutations.push(Mutation {
                    binding: t.text.clone(),
                    inner: inner.clone(),
                    file: sf.path.clone(),
                    line: t.line,
                });
            }
        }
        if is_ident(&t.text)
            && local.contains_key(&t.text)
            && toks.get(i + 1).is_some_and(|n| n.text == ".")
            && toks.get(i + 2).is_some_and(|n| n.text == "clone")
        {
            acc.escapes.push((t.text.clone(), sf.path.clone(), t.line));
        }
        if t.text == "Rc"
            && toks.get(i + 1).is_some_and(|n| n.text == "::")
            && toks.get(i + 2).is_some_and(|n| n.text == "clone")
        {
            // Rc::clone(&path.to.handle): last ident before the closing
            // paren names the handle.
            let mut k = i + 3;
            let mut last: Option<&Token> = None;
            while k < toks.len() && toks[k].text != ")" {
                if is_ident(&toks[k].text) {
                    last = Some(&toks[k]);
                }
                k += 1;
            }
            if let Some(b) = last {
                if local.contains_key(&b.text) {
                    acc.escapes.push((b.text.clone(), sf.path.clone(), b.line));
                }
            }
        }
    }
    // Pass 3: `&mut self` method surfaces, attributed to their impl type.
    extract_mut_surfaces(toks, acc);
}

/// Starting just inside `Rc<`, return the inner type of a
/// `RefCell<inner>` if that is what the generic argument is. `from`
/// points at the first token after `Rc<`.
fn refcell_inner(toks: &[Token], from: usize) -> Option<String> {
    let mut j = from;
    // Skip a `cell ::`-style path prefix before RefCell.
    while j + 1 < toks.len() && is_ident(&toks[j].text) && toks[j + 1].text == "::" {
        j += 2;
    }
    if toks.get(j).map(|t| t.text.as_str()) != Some("RefCell")
        || toks.get(j + 1).map(|t| t.text.as_str()) != Some("<")
    {
        return None;
    }
    let mut k = j + 2;
    if toks.get(k).map(|t| t.text.as_str()) == Some("(") {
        return Some("(tuple)".to_string());
    }
    while k + 1 < toks.len() && is_ident(&toks[k].text) && toks[k + 1].text == "::" {
        k += 2;
    }
    let t = toks.get(k)?;
    if is_ident(&t.text) {
        Some(t.text.clone())
    } else {
        None
    }
}

/// Scan backward from the `Rc` token for a `name :` binding annotation,
/// skipping `&` / `mut` and any `path ::` segments.
fn binding_before(toks: &[Token], rc: usize) -> Option<String> {
    let mut b = rc.checked_sub(1)?;
    loop {
        match toks[b].text.as_str() {
            "&" | "mut" => b = b.checked_sub(1)?,
            "::" => b = b.checked_sub(2)?,
            _ => break,
        }
    }
    if toks[b].text == ":" {
        let prev = toks.get(b.checked_sub(1)?)?;
        if is_ident(&prev.text) {
            return Some(prev.text.clone());
        }
    }
    None
}

/// Find `impl [<..>] Type [for Target]` blocks and the `&mut self`
/// methods inside them.
fn extract_mut_surfaces(toks: &[Token], acc: &mut ModuleAccess) {
    let mut i = 0;
    while i < toks.len() {
        if toks[i].text != "impl" {
            i += 1;
            continue;
        }
        let mut j = i + 1;
        if toks.get(j).is_some_and(|t| t.text == "<") {
            j = skip_angles(toks, j);
        }
        let Some(first) = toks.get(j) else { break };
        let mut ty = first.text.clone();
        let mut k = j + 1;
        if toks.get(k).is_some_and(|t| t.text == "<") {
            k = skip_angles(toks, k);
        }
        if toks.get(k).is_some_and(|t| t.text == "for") {
            // Trait impl: the implementing type follows `for`.
            k += 1;
            while k + 1 < toks.len() && is_ident(&toks[k].text) && toks[k + 1].text == "::" {
                k += 2;
            }
            if let Some(t) = toks.get(k) {
                ty = t.text.clone();
            }
        }
        // Body: first `{` after the header, to its matching `}`.
        while k < toks.len() && toks[k].text != "{" {
            k += 1;
        }
        let end = skip_braces(toks, k);
        let mut f = k;
        while f < end.min(toks.len()) {
            if toks[f].text == "fn" && toks.get(f + 1).is_some_and(|t| is_ident(&t.text)) {
                let name = toks[f + 1].text.clone();
                let mut p = f + 2;
                if toks.get(p).is_some_and(|t| t.text == "<") {
                    p = skip_angles(toks, p);
                }
                if toks.get(p).is_some_and(|t| t.text == "(")
                    && toks.get(p + 1).is_some_and(|t| t.text == "&")
                    && toks.get(p + 2).is_some_and(|t| t.text == "mut")
                    && toks.get(p + 3).is_some_and(|t| t.text == "self")
                {
                    acc.mut_surfaces.push((ty.clone(), name, toks[f].line));
                }
            }
            f += 1;
        }
        i = end;
    }
}

fn skip_angles(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "<" => depth += 1,
            ">" => {
                depth -= 1;
                if depth <= 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    k
}

pub(crate) fn skip_braces(toks: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut k = open;
    while k < toks.len() {
        match toks[k].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth <= 0 {
                    return k + 1;
                }
            }
            _ => {}
        }
        k += 1;
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lexer::lex;
    use crate::lints::FileClass;

    fn graph_of(module: &str, src: &str) -> StateGraph {
        StateGraph::build(&[SourceFile {
            path: PathBuf::from(format!("{module}.rs")),
            class: FileClass { sim: true, ..FileClass::default() },
            module: Some(module.to_string()),
            lexed: lex(src),
        }])
    }

    #[test]
    fn handles_defs_and_mutations_are_extracted() {
        let src = "pub struct Ledger { pub n: u64 }\n\
                   fn attach(ledger: Rc<RefCell<Ledger>>, log: &Rc<RefCell<Vec<u64>>>) {\n\
                   ledger.borrow_mut().n += 1;\nlet l2 = Rc::clone(&ledger);\n}\n";
        let g = graph_of("faas", src);
        let acc = &g.modules["faas"];
        assert_eq!(g.def_site("Ledger"), Some("faas"));
        let inners: Vec<&str> = acc.handles.iter().map(|h| h.inner.as_str()).collect();
        assert_eq!(inners, ["Ledger", "Vec"]);
        assert_eq!(acc.handles[0].binding.as_deref(), Some("ledger"));
        assert_eq!(acc.handles[0].line, 2);
        assert_eq!(acc.mutations.len(), 1);
        assert_eq!((acc.mutations[0].inner.as_str(), acc.mutations[0].line), ("Ledger", 3));
        assert_eq!(acc.escapes.len(), 1);
    }

    #[test]
    fn type_position_handles_and_paths_resolve() {
        let src = "impl Target for Rc<RefCell<Cluster>> { fn go(&mut self) {} }\n\
                   fn mk() -> std::rc::Rc<cell::RefCell<Cluster>> { todo!() }\n";
        let g = graph_of("workload", src);
        let acc = &g.modules["workload"];
        assert_eq!(acc.handles.len(), 2);
        assert!(acc.handles.iter().all(|h| h.inner == "Cluster" && h.binding.is_none()));
        // &mut self surface attributed to the trait-impl target type.
        assert_eq!(acc.mut_surfaces.len(), 1);
        assert_eq!(acc.mut_surfaces[0].1, "go");
    }

    #[test]
    fn builtins_and_tuples_are_not_shard_state() {
        assert!(is_builtin("Vec"));
        assert!(is_builtin("(tuple)"));
        assert!(is_builtin("i64"));
        assert!(!is_builtin("Cluster"));
        let g = graph_of("faas", "fn f(g: Rc<RefCell<(u64, Time)>>) {}\n");
        assert_eq!(g.modules["faas"].handles[0].inner, "(tuple)");
    }
}
