//! xtask — repo automation for junctiond-repro.
//!
//! Subcommands: `detlint` (see `lints`) — a static determinism /
//! conservation / shard-safety pass over the crate built on the `graph`
//! state-access analysis and the checked-in `shard_map.toml` — and
//! `schedcheck`, which builds the repro binary and runs the E17
//! tie-break schedule explorer. Both run in CI next to the dynamic
//! same-seed byte-diff. Library form so the fixture tests in
//! `xtask/tests/` can drive the linter in-process.

pub mod graph;
pub mod lexer;
pub mod lints;
pub mod scan;
pub mod shard_map;
