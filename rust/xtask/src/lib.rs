//! xtask — repo automation for junctiond-repro.
//!
//! The one subcommand today is `detlint` (see `lints`): a static
//! determinism-and-conservation pass over the crate, run in CI next to
//! the dynamic same-seed byte-diff. Library form so the fixture tests in
//! `xtask/tests/` can drive the linter in-process.

pub mod lexer;
pub mod lints;
pub mod scan;
