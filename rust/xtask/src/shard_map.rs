//! `shard_map.toml` — the checked-in declaration of every cross-module
//! shared-state handle and each module's shard domain.
//!
//! Parsed with a hand-rolled TOML *subset* (sections, `key = "value"`
//! pairs, `#` comments) for the same reason the lexer is hand-rolled:
//! the offline registry has no `toml` crate. The subset is exactly what
//! the schema needs; anything else is a loud parse error, never a
//! silent skip — an unparsed declaration would hide an L5 violation.
//!
//! Schema:
//!
//! ```toml
//! [modules]
//! faas = "gateway"          # module name -> shard domain
//!
//! [state.Cluster]           # one section per declared shared type
//! owner = "faas"            # module that defines the struct
//! domain = "gateway"        # shard domain the state lives in
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// The shard domains a module or state type may be declared in. Kept in
/// sync with the header comment of `xtask/shard_map.toml` and DESIGN.md
/// §3i.
pub const DOMAINS: [&str; 6] =
    ["per_worker", "gateway", "wire", "control", "global_readonly", "value"];

/// One `[state.T]` declaration.
#[derive(Debug, Clone)]
pub struct StateDecl {
    pub owner: String,
    pub domain: String,
    /// Line of the `[state.T]` header (for diagnostics).
    pub line: u32,
}

/// Parsed shard map.
#[derive(Debug, Default)]
pub struct ShardMap {
    /// Path the map was read from (diagnostics point here).
    pub path: PathBuf,
    /// `[modules]`: module name → shard domain, with declaration line.
    pub modules: BTreeMap<String, (String, u32)>,
    /// `[state.T]`: type name → declaration.
    pub state: BTreeMap<String, StateDecl>,
}

/// Parse errors as `(line, message)`; the caller turns them into
/// violations against the map file itself.
pub fn parse(path: &Path, src: &str) -> Result<ShardMap, Vec<(u32, String)>> {
    let mut map = ShardMap { path: path.to_path_buf(), ..ShardMap::default() };
    let mut errors: Vec<(u32, String)> = Vec::new();
    // Current section: None (preamble), modules, or a state type.
    enum Section {
        None,
        Modules,
        State(String, u32),
    }
    let mut section = Section::None;
    // Pending fields of the open [state.T] section.
    let mut owner: Option<String> = None;
    let mut domain: Option<String> = None;
    let mut close = |map: &mut ShardMap,
                     errors: &mut Vec<(u32, String)>,
                     section: &Section,
                     owner: &mut Option<String>,
                     domain: &mut Option<String>| {
        if let Section::State(ty, line) = section {
            match (owner.take(), domain.take()) {
                (Some(o), Some(d)) => {
                    let decl = StateDecl { owner: o, domain: d, line: *line };
                    if map.state.insert(ty.clone(), decl).is_some() {
                        errors.push((*line, format!("duplicate [state.{ty}] section")));
                    }
                }
                (o, d) => {
                    if o.is_none() {
                        errors.push((*line, format!("[state.{ty}] is missing `owner`")));
                    }
                    if d.is_none() {
                        errors.push((*line, format!("[state.{ty}] is missing `domain`")));
                    }
                }
            }
        }
    };
    for (idx, raw) in src.lines().enumerate() {
        let line = idx as u32 + 1;
        let text = match raw.split_once('#') {
            Some((before, _)) => before.trim(),
            None => raw.trim(),
        };
        if text.is_empty() {
            continue;
        }
        if let Some(inner) = text.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
            close(&mut map, &mut errors, &section, &mut owner, &mut domain);
            section = if inner == "modules" {
                Section::Modules
            } else if let Some(ty) = inner.strip_prefix("state.") {
                if ty.is_empty() {
                    errors.push((line, "empty type in [state.] section".to_string()));
                    Section::None
                } else {
                    Section::State(ty.to_string(), line)
                }
            } else {
                errors.push((line, format!("unknown section [{inner}]")));
                Section::None
            };
            continue;
        }
        let Some((key, val)) = text.split_once('=') else {
            errors.push((line, format!("expected `key = \"value\"`, got {text:?}")));
            continue;
        };
        let key = key.trim();
        let val = val.trim();
        let Some(val) = val.strip_prefix('"').and_then(|v| v.strip_suffix('"')) else {
            errors.push((line, format!("value for `{key}` must be a double-quoted string")));
            continue;
        };
        match &section {
            Section::None => {
                errors.push((line, format!("`{key}` outside any section")));
            }
            Section::Modules => {
                if !DOMAINS.contains(&val) {
                    errors.push((line, format!("unknown domain {val:?} for module `{key}`")));
                }
                if map.modules.insert(key.to_string(), (val.to_string(), line)).is_some() {
                    errors.push((line, format!("duplicate module entry `{key}`")));
                }
            }
            Section::State(ty, _) => match key {
                "owner" => owner = Some(val.to_string()),
                "domain" => {
                    if !DOMAINS.contains(&val) {
                        errors.push((line, format!("unknown domain {val:?} in [state.{ty}]")));
                    }
                    domain = Some(val.to_string());
                }
                other => {
                    errors.push((line, format!("unknown key `{other}` in [state.{ty}]")));
                }
            },
        }
    }
    close(&mut map, &mut errors, &section, &mut owner, &mut domain);
    if errors.is_empty() {
        Ok(map)
    } else {
        Err(errors)
    }
}

/// Load the map at `path`; `Ok(None)` when the file does not exist (the
/// caller decides whether absence is an error — it is in repo mode).
pub fn load(path: &Path) -> Result<Option<ShardMap>, Vec<(u32, String)>> {
    match std::fs::read_to_string(path) {
        Ok(src) => parse(path, &src).map(Some),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(None),
        Err(e) => Err(vec![(0, format!("cannot read {}: {e}", path.display()))]),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(src: &str) -> Result<ShardMap, Vec<(u32, String)>> {
        parse(Path::new("test.toml"), src)
    }

    #[test]
    fn parses_modules_and_state_sections() {
        let src = "# header\n[modules]\nfaas = \"gateway\" # inline\n\n\
                   [state.Cluster]\nowner = \"faas\"\ndomain = \"gateway\"\n";
        let m = p(src).unwrap();
        assert_eq!(m.modules.get("faas").map(|(d, _)| d.as_str()), Some("gateway"));
        let c = m.state.get("Cluster").unwrap();
        assert_eq!((c.owner.as_str(), c.domain.as_str()), ("faas", "gateway"));
        assert_eq!(c.line, 5);
    }

    #[test]
    fn rejects_unknown_domains_and_incomplete_sections() {
        let errs = p("[modules]\nfaas = \"galaxy\"\n").unwrap_err();
        assert!(errs[0].1.contains("unknown domain"), "{errs:?}");
        let errs = p("[state.Rng]\nowner = \"simcore\"\n").unwrap_err();
        assert!(errs[0].1.contains("missing `domain`"), "{errs:?}");
        let errs = p("[state.X]\nowner = unquoted\ndomain = \"value\"\n").unwrap_err();
        assert!(errs[0].1.contains("double-quoted"), "{errs:?}");
    }

    #[test]
    fn rejects_duplicates_and_stray_keys() {
        let errs = p("[modules]\na = \"wire\"\na = \"wire\"\n").unwrap_err();
        assert!(errs[0].1.contains("duplicate module"), "{errs:?}");
        let errs = p("stray = \"value\"\n").unwrap_err();
        assert!(errs[0].1.contains("outside any section"), "{errs:?}");
        let src = "[state.T]\nowner = \"a\"\ndomain = \"value\"\ncolor = \"red\"\n";
        let errs = p(src).unwrap_err();
        assert!(errs[0].1.contains("unknown key"), "{errs:?}");
    }
}
