//! Bench E12 — `density_scale`: drive the rebuilt engine to the regime
//! the ROADMAP's north star demands (millions of registered functions,
//! tens of millions of invocations) and record the host-side engine
//! numbers in `BENCH_engine.json`.
//!
//! Full mode sweeps up to **1M registered functions / ≥10M simulated
//! invocations** on an 8×16-core junctiond cluster (minutes of wall
//! clock); `BENCH_QUICK=1` runs a scaled-down sweep as the CI smoke gate.
//! In both modes it asserts:
//!
//! * the sweep completes with zero NIC drops and every in-window request
//!   resolved (the harness is *driving* the load, not choking on it);
//! * the Junction-vs-containerd virtual-time latency table of an E11
//!   slice is **bit-identical** under the wheel and the seed's reference
//!   heap (determinism preserved under the new engine — the tables are
//!   unchanged, only the wall clock moves).

mod common;

use std::io::Write as _;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::{set_default_engine, EngineKind, MILLIS, SECONDS};

fn json_point(p: &ex::DensityPoint) -> String {
    format!(
        "{{\"backend\":\"{}\",\"engine\":\"{}\",\"workers\":{},\"functions\":{},\
         \"hot_functions\":{},\"submitted\":{},\"completed\":{},\"dropped\":{},\
         \"virtual_secs\":{:.3},\"wall_secs\":{:.3},\"events_fired\":{},\
         \"events_per_sec\":{:.0},\"p50_us\":{:.1},\"p99_us\":{:.1}}}",
        p.backend.name(),
        p.engine,
        p.workers,
        p.functions,
        p.hot_functions,
        p.submitted,
        p.completed,
        p.dropped,
        p.virtual_ns as f64 / SECONDS as f64,
        p.wall_secs,
        p.events_fired,
        p.events_per_sec,
        p.p50 as f64 / 1_000.0,
        p.p99 as f64 / 1_000.0,
    )
}

fn main() {
    let quick = common::quick();
    let mut checks = common::Checks::new();
    let mut points: Vec<ex::DensityPoint> = Vec::new();

    common::section("E12 — density_scale sweep", || {
        // (workers, cores, functions, hot, rate rps, duration). The full
        // ladder ends at the headline point: 1M registered functions,
        // 250k rps for 40 s ≈ 10M in-window (11M simulated) invocations.
        let sweep: Vec<(usize, usize, u64, usize, f64, u64)> = if quick {
            vec![
                (2, 10, 10_000, 256, 10_000.0, 500 * MILLIS),
                (4, 16, 50_000, 1_024, 40_000.0, 500 * MILLIS),
            ]
        } else {
            vec![
                (4, 16, 100_000, 2_048, 100_000.0, 5 * SECONDS),
                (8, 16, 1_000_000, 4_096, 250_000.0, 40 * SECONDS),
            ]
        };
        for (workers, cores, functions, hot, rate, duration) in sweep {
            let p = ex::density_scale_run(
                Backend::Junctiond,
                workers,
                cores,
                functions,
                hot,
                rate,
                duration,
                3,
            );
            println!(
                "functions={} submitted={} completed={} dropped={} wall={:.1}s \
                 events={} → {:.0} events/s p99={}µs",
                p.functions,
                p.submitted,
                p.completed,
                p.dropped,
                p.wall_secs,
                p.events_fired,
                p.events_per_sec,
                p.p99 / 1_000
            );
            checks.check(
                "every in-window request resolved",
                p.completed + p.dropped == p.submitted,
                format!("{} + {} vs {}", p.completed, p.dropped, p.submitted),
            );
            checks.check(
                "bypass cluster sheds nothing at the offered rate",
                p.dropped == 0,
                format!("{} dropped", p.dropped),
            );
            points.push(p);
        }
        let table = ex::density_scale_table(&points);
        println!("{}", table.to_markdown());
        if !quick {
            let last = points.last().unwrap();
            checks.check(
                "headline point reaches ≥1M functions / ≥10M simulated invocations",
                last.functions >= 1_000_000 && last.submitted >= 10_000_000,
                format!("{} fns, {} submitted", last.functions, last.submitted),
            );
        }
    });

    common::section("E12 — latency tables bit-identical across engines", || {
        let rates = [1_000.0, 3_000.0];
        let dur = if quick { 150 * MILLIS } else { 400 * MILLIS };
        let run = || {
            let (t, _) = ex::netpath_table(2, 10, &rates, &rates, dur, 7);
            t.to_markdown()
        };
        let wheel = run();
        let prev = set_default_engine(EngineKind::ReferenceHeap);
        let heap = run();
        set_default_engine(prev);
        checks.check(
            "Junction-vs-containerd table identical under wheel and seed heap",
            wheel == heap,
            format!("{} bytes", wheel.len()),
        );
    });

    // Record the measured numbers (satellite: BENCH_engine.json). Written
    // to the repo root when run from `rust/` (cargo bench's cwd).
    let path = junctiond_repro::hostclock::env_var("BENCH_ENGINE_JSON")
        .unwrap_or_else(|| "../BENCH_engine.json".into());
    let body = format!(
        "{{\n  \"experiment\": \"E12 density_scale\",\n  \"quick\": {},\n  \"points\": [\n    {}\n  ]\n}}\n",
        quick,
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n    ")
    );
    match std::fs::File::create(&path).and_then(|mut f| f.write_all(body.as_bytes())) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }

    checks.finish();
}
