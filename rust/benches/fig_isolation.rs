//! Bench E14: structural isolation under co-location. One 10-core worker
//! hosts a latency-sensitive function (platform-default ~100 µs body,
//! 400 rps) next to a sweep of antagonist tenants (2 ms bodies, 400
//! rps/tenant each); residual jitter is off, so every microsecond of
//! tail comes from per-core contention in the compute fabric.
//!
//! Asserts the paper's Figure-direction isolation result structurally:
//! the kernel backend's P99 for the co-located function degrades
//! super-linearly as antagonist load sweeps up (CFS timeslices, softirq
//! stealing, wakeup migration pile onto shared per-core timelines) while
//! the bypass backend holds the tail within a bounded factor (fair-share
//! core grants preempt at the Junction scheduler's fine regrant quantum).
//! Also gates conservation: per-core busy time sums to the fabric total
//! and every issued segment completes — the interference is real work on
//! real cores, not an accounting artifact.

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::MILLIS;

fn main() {
    let duration = if common::quick() { 200 * MILLIS } else { 500 * MILLIS };

    common::section("E14 — structural interference sweep", || {
        let counts = ex::interference_default_counts();
        let (table, points) = ex::interference_table(&counts, 400.0, 2 * MILLIS, duration, 5);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();
        let find = |b: Backend, n: u32| {
            points.iter().find(|p| p.backend == b && p.antagonists == n).expect("point")
        };
        let top = *counts.last().unwrap();
        let mid = counts[counts.len() / 2];
        let k0 = find(Backend::Containerd, 0);
        let kmid = find(Backend::Containerd, mid);
        let ktop = find(Backend::Containerd, top);
        let j0 = find(Backend::Junctiond, 0);
        let jtop = find(Backend::Junctiond, top);

        checks.check(
            "kernel p99 degrades ≥5× at the top antagonist load",
            ktop.p99 as f64 > 5.0 * k0.p99 as f64,
            format!("{} µs → {} µs", k0.p99 / 1000, ktop.p99 / 1000),
        );
        // Super-linear: the degradation over idle more than doubles from
        // the mid point to the top point (load only doubles).
        let d_mid = kmid.p99.saturating_sub(k0.p99).max(1) as f64;
        let d_top = ktop.p99.saturating_sub(k0.p99) as f64;
        checks.check(
            "kernel degradation is super-linear in antagonist load",
            d_top > 2.0 * d_mid,
            format!("Δp99 {:.0} µs @{mid} → {:.0} µs @{top}", d_mid / 1000.0, d_top / 1000.0),
        );
        checks.check(
            "bypass p99 stays within 4× of its idle baseline",
            (jtop.p99 as f64) < 4.0 * j0.p99 as f64,
            format!("{} µs → {} µs", j0.p99 / 1000, jtop.p99 / 1000),
        );
        checks.check(
            "bypass pointwise win survives co-location",
            jtop.p99 < ktop.p99,
            format!("{} µs vs {} µs", jtop.p99 / 1000, ktop.p99 / 1000),
        );

        // The interference is structural churn, not sampled noise.
        checks.check(
            "kernel fabric timeslices under load",
            ktop.fabric.preemptions > 0 && ktop.fabric.migrations > 0,
            format!("preempt {} migrations {}", ktop.fabric.preemptions, ktop.fabric.migrations),
        );
        let kernel_steals: u64 = points
            .iter()
            .filter(|p| p.backend == Backend::Containerd)
            .map(|p| p.fabric.steals)
            .sum();
        checks.check(
            "idle kernel cores steal backlogged softirq work",
            kernel_steals > 0,
            format!("{kernel_steals} steals across the sweep"),
        );
        checks.check(
            "bypass regrants preempt at quantum edges",
            jtop.fabric.preemptions > 0,
            format!("{}", jtop.fabric.preemptions),
        );

        // Conservation: per-core busy time sums to the fabric total, and
        // fabric jobs == segments issued == segments completed.
        let conserved = points.iter().all(|p| {
            p.fabric.per_core_busy_ns.iter().sum::<u64>() == p.fabric.busy_ns
                && p.fabric.jobs_submitted == p.fabric.jobs_completed
        });
        checks.check(
            "fabric conservation (Σ per-core busy == total; submitted == completed)",
            conserved,
            format!("{} points", points.len()),
        );
        checks.finish();
    });
}
