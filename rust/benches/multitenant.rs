//! Bench E10 (§1 motivation): multi-tenant Zipf trace with lazy
//! scale-from-zero deploys — the "serverless in the wild" shape the paper
//! cites [22]. Junction's ms-scale instance starts and cheap wakeups keep
//! the tail bounded where containerd's cold starts dominate it.

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    let (funcs, rps) = if common::quick() { (20, 400.0) } else { (60, 1_000.0) };
    common::section("Multi-tenant trace replay", || {
        let table = ex::multitenant_table(funcs, rps, 9);
        println!("{}", table.to_markdown());
        let us = |r: usize, c: usize| match &table.rows[r][c] {
            Cell::NsAsUs(v) => *v,
            _ => unreachable!(),
        };
        let mut checks = common::Checks::new();
        checks.check(
            "junctiond p99 below containerd p99",
            us(1, 4) < us(0, 4),
            format!("{}µs vs {}µs", us(1, 4) / 1000, us(0, 4) / 1000),
        );
        checks.check(
            "containerd tail carries cold starts (≥100ms)",
            us(0, 4) > 100_000_000,
            format!("{}ms", us(0, 4) / 1_000_000),
        );
        checks.check(
            "junctiond tail stays in single-digit ms",
            us(1, 4) < 20_000_000,
            format!("{}ms", us(1, 4) / 1_000_000),
        );
        checks.finish();
    });
}
