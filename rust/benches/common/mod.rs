//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Every bench binary (`harness = false`) regenerates one paper artifact
//! (table or figure) and prints it as markdown, then asserts the *shape*
//! band from DESIGN.md §3 so `cargo bench` doubles as a reproduction
//! check. `BENCH_QUICK=1` shrinks the workloads for smoke runs.
#![allow(dead_code)] // each bench binary uses a different subset

use junctiond_repro::hostclock::{env_var, Stopwatch};

pub fn quick() -> bool {
    env_var("BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Run a named section, timing wall clock.
pub fn section<F: FnOnce()>(name: &str, f: F) {
    println!("\n==== {name} ====");
    let sw = Stopwatch::new();
    f();
    println!("---- {name}: {:.2}s ----", sw.elapsed_secs());
}

/// Time a closure over `iters` iterations, reporting ns/iter.
pub fn time_it<F: FnMut()>(label: &str, iters: u32, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let sw = Stopwatch::new();
    for _ in 0..iters {
        f();
    }
    let per = sw.elapsed_ns() as f64 / iters as f64;
    println!("{label:<44} {per:>12.0} ns/iter   ({iters} iters)");
    per
}

/// Soft assertion: print PASS/FAIL and remember failures (exit code).
pub struct Checks {
    failures: Vec<String>,
}

impl Checks {
    pub fn new() -> Checks {
        Checks { failures: Vec::new() }
    }

    pub fn check(&mut self, name: &str, ok: bool, detail: String) {
        if ok {
            println!("CHECK PASS: {name} ({detail})");
        } else {
            println!("CHECK FAIL: {name} ({detail})");
            self.failures.push(name.to_string());
        }
    }

    pub fn finish(self) {
        if !self.failures.is_empty() {
            panic!("bench shape checks failed: {:?}", self.failures);
        }
    }
}
