//! Bench E18 — `shard_scale`: the parallel shard runner (DESIGN.md §3j)
//! on the E12 density workload, recording the host-side numbers in
//! `BENCH_shard.json`.
//!
//! Full mode drives the headline point — 100k registered functions on a
//! 16-rack cluster at 2.5M rps for 40 virtual seconds ≈ **100M+
//! simulated invocations** — at 1, 2, 4, and 8 shards, and asserts the
//! ISSUE 10 gate: ≥4× wall-clock speedup at 8 shards vs `--shards 1`
//! (only when the host actually exposes ≥8 cores — on smaller runners
//! the speedup is reported but not asserted). `BENCH_QUICK=1` runs a
//! scaled-down sweep as the CI smoke gate.
//!
//! In both modes it asserts the determinism contract:
//!
//! * the deterministic table is byte-identical across shards ∈ {1,2,4,8};
//! * the threaded transport matches the serial (inline) transport byte
//!   for byte at the same shard count;
//! * every run conserves requests and passes the per-rack + merged
//!   audits (`shard_scale_run` panics otherwise).

mod common;

use std::io::Write as _;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::hostclock::host_parallelism;
use junctiond_repro::simcore::{MILLIS, SECONDS};

const SEED: u64 = 18;

struct Shape {
    workers: usize,
    cores: usize,
    functions: u64,
    hot: usize,
    rate: f64,
    duration: u64,
}

fn run(shape: &Shape, shards: usize, threaded: bool) -> ex::ShardScalePoint {
    ex::shard_scale_run(
        Backend::Junctiond,
        shards,
        threaded,
        shape.workers,
        shape.cores,
        shape.functions,
        shape.hot,
        shape.rate,
        shape.duration,
        SEED,
    )
}

/// The table bytes with the shard count and transport (the two
/// legitimately varying cells) neutralized, for cross-N equality checks.
fn normalized_table(p: &ex::ShardScalePoint) -> String {
    let mut p = p.clone();
    p.shards = 0;
    p.transport = "-";
    ex::shard_scale_table(std::slice::from_ref(&p)).to_markdown()
}

fn json_point(p: &ex::ShardScalePoint) -> String {
    format!(
        "{{\"backend\":\"{}\",\"shards\":{},\"transport\":\"{}\",\"workers\":{},\
         \"functions\":{},\"hot_functions\":{},\"submitted\":{},\"completed\":{},\
         \"dropped\":{},\"timed_out\":{},\"events_fired\":{},\"wall_secs\":{:.3},\
         \"events_per_sec\":{:.0},\"p50_us\":{:.1},\"p99_us\":{:.1}}}",
        p.backend.name(),
        p.shards,
        p.transport,
        p.workers,
        p.functions,
        p.hot_functions,
        p.submitted,
        p.completed,
        p.dropped,
        p.timed_out,
        p.events_fired,
        p.wall_secs,
        p.events_fired as f64 / p.wall_secs.max(1e-9),
        p.p50 as f64 / 1_000.0,
        p.p99 as f64 / 1_000.0,
    )
}

fn main() {
    let quick = common::quick();
    let mut checks = common::Checks::new();
    let mut points: Vec<ex::ShardScalePoint> = Vec::new();

    // Quick keeps CI smoke under a minute; full is the headline regime:
    // 2.5M rps × 40 virtual seconds ≈ 100M in-window (111M simulated
    // with warm-up) invocations across 16 racks.
    let shape = if quick {
        Shape {
            workers: 8,
            cores: 8,
            functions: 5_000,
            hot: 256,
            rate: 20_000.0,
            duration: 500 * MILLIS,
        }
    } else {
        Shape {
            workers: 16,
            cores: 16,
            functions: 100_000,
            hot: 1_024,
            rate: 2_500_000.0,
            duration: 40 * SECONDS,
        }
    };

    common::section("E18 — determinism across shard counts", || {
        let mut base: Option<String> = None;
        for shards in [1usize, 2, 4, 8] {
            let p = run(&shape, shards, true);
            println!(
                "shards={} submitted={} completed={} wall={:.1}s events={}",
                p.shards, p.submitted, p.completed, p.wall_secs, p.events_fired
            );
            let table = normalized_table(&p);
            match &base {
                None => base = Some(table),
                Some(b) => checks.check(
                    &format!("table at {shards} shards identical to 1 shard"),
                    &table == b,
                    format!("{} bytes", table.len()),
                ),
            }
            points.push(p);
        }
        checks.check(
            "workload is non-trivial",
            points[0].submitted > 1_000,
            format!("{} submitted", points[0].submitted),
        );
        if !quick {
            checks.check(
                "headline point reaches ≥100M simulated invocations",
                points[0].submitted >= 100_000_000,
                format!("{} submitted", points[0].submitted),
            );
        }
    });

    common::section("E18 — serial transport == threaded transport", || {
        let serial = run(&shape_small(&shape, quick), 4, false);
        let threaded = run(&shape_small(&shape, quick), 4, true);
        let a = normalized_table(&serial);
        let b = normalized_table(&threaded);
        checks.check("serial and threaded tables identical", a == b, format!("{} bytes", a.len()));
    });

    common::section("E18 — wall-clock speedup", || {
        let wall = |shards: usize| {
            points.iter().find(|p| p.shards == shards).map(|p| p.wall_secs).unwrap_or(f64::NAN)
        };
        let speedup = wall(1) / wall(8).max(1e-9);
        let cores = host_parallelism();
        println!(
            "host cores={} wall(1)={:.1}s wall(8)={:.1}s speedup={:.2}x",
            cores,
            wall(1),
            wall(8),
            speedup
        );
        if !quick && cores >= 8 {
            checks.check(
                "≥4x speedup at 8 shards on ≥8 host cores",
                speedup >= 4.0,
                format!("{speedup:.2}x"),
            );
        } else {
            println!("(speedup gate skipped: quick={quick}, host cores={cores})");
        }
    });

    // Record the measured numbers (satellite: BENCH_shard.json). Written
    // to the repo root when run from `rust/` (cargo bench's cwd).
    let path = junctiond_repro::hostclock::env_var("BENCH_SHARD_JSON")
        .unwrap_or_else(|| "../BENCH_shard.json".into());
    let body = format!(
        "{{\n  \"experiment\": \"E18 shard_scale\",\n  \"quick\": {},\n  \"host_cores\": {},\n  \"points\": [\n    {}\n  ]\n}}\n",
        quick,
        host_parallelism(),
        points.iter().map(json_point).collect::<Vec<_>>().join(",\n    ")
    );
    match std::fs::File::create(&path).and_then(|mut f| f.write_all(body.as_bytes())) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }

    checks.finish();
}

/// The serial-vs-threaded leg re-runs the workload twice more, so full
/// mode shrinks it to a slice (equality is shape-independent; no reason
/// to pay 2×40 virtual seconds for it).
fn shape_small(shape: &Shape, quick: bool) -> Shape {
    Shape {
        workers: shape.workers,
        cores: shape.cores,
        functions: shape.functions.min(5_000),
        hot: shape.hot.min(256),
        rate: if quick { shape.rate } else { 50_000.0 },
        duration: 500 * MILLIS,
    }
}
