//! Bench E2 / Figure 6: gateway-observed response time vs offered load.
//! Asserts: junctiond sustains ≥5× the throughput under a 5 ms p99 SLA
//! (paper: 10×) and wins latency at every pre-knee load.

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::{MILLIS, SECONDS};

fn main() {
    let duration = if common::quick() { SECONDS / 2 } else { SECONDS };
    common::section("Figure 6 — response time vs offered load", || {
        let rates = ex::fig6_default_rates();
        let (table, points) = ex::fig6_table(&rates, duration, 3);
        println!("{}", table.to_markdown());

        let sla = 5 * MILLIS;
        let kc = ex::knee(&points, Backend::Containerd, sla);
        let kj = ex::knee(&points, Backend::Junctiond, sla);
        let ratio = kj / kc.max(1.0);
        println!("knee: containerd {kc:.0} rps, junctiond {kj:.0} rps → {ratio:.1}×");

        let mut checks = common::Checks::new();
        checks.check("throughput knee ratio (paper ~10×)", ratio >= 5.0, format!("{ratio:.1}×"));
        // Latency dominance below containerd's knee.
        let pre_knee_ok = points
            .iter()
            .filter(|p| p.backend == Backend::Containerd && p.offered_rps <= kc)
            .all(|c| {
                points
                    .iter()
                    .find(|j| j.backend == Backend::Junctiond && j.offered_rps == c.offered_rps)
                    .map(|j| j.p50 < c.p50 && j.p99 < c.p99)
                    .unwrap_or(false)
            });
        checks.check("junctiond wins p50+p99 at every pre-knee load", pre_knee_ok, "pointwise".into());
        // Median ~2×, tail ~3.5× at moderate load (paper's Fig. 6 text).
        if let (Some(c), Some(j)) = (
            points.iter().find(|p| p.backend == Backend::Containerd && p.offered_rps == 2000.0),
            points.iter().find(|p| p.backend == Backend::Junctiond && p.offered_rps == 2000.0),
        ) {
            let m = c.p50 as f64 / j.p50 as f64;
            let t = c.p99 as f64 / j.p99 as f64;
            checks.check("median ratio @2k rps (paper ~2×)", (1.3..4.0).contains(&m), format!("{m:.1}×"));
            checks.check("p99 ratio @2k rps (paper ~3.5×)", (1.8..9.0).contains(&t), format!("{t:.1}×"));
        }
        checks.finish();
    });
}
