//! Bench E11 / Figure 6 at cluster scale: the network data path under
//! load. Two 16-core workers behind the least-inflight front end, every
//! request crossing each worker's bounded NIC RX ring as a framed RPC.
//!
//! Asserts the paper's headline shape from the *network model* (not a flat
//! constant): junctiond sustains ≥10× the containerd saturation
//! throughput under a 5 ms p99 SLA, wins p50+p99 at every pre-knee rate,
//! and the kernel path's ring sheds (drops + retries) at overload while
//! the polled path never drops in-grid.

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::MILLIS;

fn main() {
    let duration = if common::quick() { 200 * MILLIS } else { 500 * MILLIS };
    common::section("Figure 6 (cluster) — network data path load sweep", || {
        let c_rates = ex::netpath_default_containerd_rates();
        let j_rates = ex::netpath_default_junction_rates();
        let (table, points) = ex::netpath_table(2, 16, &c_rates, &j_rates, duration, 3);
        println!("{}", table.to_markdown());

        let sla = 5 * MILLIS;
        let kc = ex::netpath_knee(&points, Backend::Containerd, sla);
        let kj = ex::netpath_knee(&points, Backend::Junctiond, sla);
        let ratio = kj / kc.max(1.0);
        println!("cluster knee: containerd {kc:.0} rps, junctiond {kj:.0} rps → {ratio:.1}×");

        let mut checks = common::Checks::new();
        checks.check(
            "junctiond sustains ≥10× containerd saturation (paper: 10×)",
            ratio >= 10.0,
            format!("{ratio:.1}×"),
        );
        // Latency dominance at every offered rate below the containerd knee.
        let pre_knee_ok = points
            .iter()
            .filter(|p| p.backend == Backend::Containerd && p.offered_rps <= kc)
            .all(|c| {
                points
                    .iter()
                    .find(|j| {
                        j.backend == Backend::Junctiond && j.offered_rps == c.offered_rps
                    })
                    .map(|j| j.p50 < c.p50 && j.p99 < c.p99)
                    .unwrap_or(true)
            });
        checks.check(
            "junctiond wins p50+p99 at every pre-knee rate",
            pre_knee_ok,
            "pointwise".into(),
        );
        // Per-hop breakdown: the polled NIC hop undercuts the kernel one
        // at the shared low rate.
        let hop_ok = match (
            points
                .iter()
                .find(|p| p.backend == Backend::Containerd && p.offered_rps == 1_000.0),
            points
                .iter()
                .find(|p| p.backend == Backend::Junctiond && p.offered_rps == 1_000.0),
        ) {
            (Some(c), Some(j)) => j.nic_p50 < c.nic_p50 && c.exec_p50 > 0 && j.exec_p50 > 0,
            _ => false,
        };
        checks.check("polled NIC hop beats kernel NIC hop @1k rps", hop_ok, "per-hop".into());
        // Drop/retry accounting: the kernel ring sheds past its packet
        // rate; the polled ring never drops anywhere in the grid.
        let stress = points
            .iter()
            .find(|p| p.backend == Backend::Containerd && p.offered_rps >= 100_000.0);
        checks.check(
            "kernel NIC ring sheds at overload (drops + retries)",
            stress.map(|p| p.dropped > 0 && p.retries > 0).unwrap_or(false),
            stress
                .map(|p| format!("dropped {} retries {}", p.dropped, p.retries))
                .unwrap_or_else(|| "missing stress point".into()),
        );
        let bypass_clean =
            points.iter().filter(|p| p.backend == Backend::Junctiond).all(|p| p.dropped == 0);
        checks.check("bypass path never drops in-grid", bypass_clean, "0 drops".into());
        checks.finish();
    });
}
