//! Bench E4 (§4): provider metadata cache on/off. With the cache off,
//! every invocation pays a backend state query — for containerd that round
//! trip is "slower than the function invocation itself" (paper §4).

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::MICROS;
use junctiond_repro::telemetry::Cell;

fn main() {
    let n = if common::quick() { 50 } else { 200 };
    common::section("Ablation — provider metadata cache", || {
        let table = ex::ablation_cache_table(n, 2);
        println!("{}", table.to_markdown());
        let p50 = |row: usize| match &table.rows[row][2] {
            Cell::NsAsUs(v) => *v,
            _ => unreachable!(),
        };
        // Rows: 0 containerd/on, 1 containerd/off, 2 junctiond/on, 3 junctiond/off.
        let mut checks = common::Checks::new();
        checks.check(
            "containerd: cache off ≫ on (state query dominates)",
            p50(1) > p50(0) + 500 * MICROS,
            format!("{}µs vs {}µs", p50(1) / MICROS, p50(0) / MICROS),
        );
        checks.check(
            "junctiond: cache off penalty exists but is small",
            p50(3) > p50(2) && p50(3) < p50(2) + 200 * MICROS,
            format!("{}µs vs {}µs", p50(3) / MICROS, p50(2) / MICROS),
        );
        checks.check(
            "cached junctiond beats cached containerd",
            p50(2) < p50(0),
            format!("{}µs vs {}µs", p50(2) / MICROS, p50(0) / MICROS),
        );
        checks.finish();
    });
}
