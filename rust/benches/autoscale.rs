//! Bench E9 (§2.1): controller autoscaling on the 4-worker cluster under a
//! step load. The controller must grow replicas in the high phase and shed
//! them when idle, on both backends.

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    common::section("Autoscaling — step load on a 4-worker pool", || {
        let mut checks = common::Checks::new();
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let table = ex::autoscale_table(backend, 3);
            println!("{}", table.to_markdown());
            let peak = |r: usize| match &table.rows[r][2] {
                Cell::Int(v) => *v,
                _ => unreachable!(),
            };
            checks.check(
                &format!("{}: high phase grows replicas", backend.name()),
                peak(1) >= peak(0),
                format!("{} → {}", peak(0), peak(1)),
            );
        }
        checks.finish();
    });
}
