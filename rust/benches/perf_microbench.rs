//! §Perf microbenchmarks: the L3 hot paths in isolation.
//!
//! * DES engine event throughput (events/s) — the simulator's own speed
//!   bounds how big a Fig. 6 sweep is practical.
//! * Pipeline submit→complete cost per simulated invocation.
//! * PJRT invoke overhead vs raw artifact compute.
//! * RPC framing encode/decode.
//! * Histogram record cost.
//!
//! Before/after numbers live in EXPERIMENTS.md §Perf.

mod common;

use std::rc::Rc;

use junctiond_repro::config::{Backend, ExperimentConfig, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::{FaasSim, FunctionSpec, RuntimeKind};
use junctiond_repro::hostclock::Stopwatch;
use junctiond_repro::rpc::Message;
use junctiond_repro::simcore::{Rng, Sim, SECONDS};
use junctiond_repro::telemetry::LogHistogram;
use junctiond_repro::workload::ClosedLoop;

fn main() {
    common::section("perf — DES engine", || {
        // 1M trivial events.
        let sw = Stopwatch::new();
        let mut sim = Sim::new();
        let mut rng = Rng::new(1);
        for _ in 0..1_000_000u32 {
            sim.at(rng.below(1_000_000_000), |_| {});
        }
        sim.run_to_completion();
        let per = sw.elapsed_ns() as f64 / 1e6;
        println!("event schedule+fire: {per:.0} ns/event ({:.1}M events/s)", 1e3 / per);
    });

    common::section("perf — simulated invocation pipeline", || {
        // Best-of-5: this environment is a shared 1-core container with
        // ±50% ambient noise; the minimum is the noise-resistant estimator.
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let n = 50_000u32;
            let mut best = f64::INFINITY;
            let mut events = 0;
            for _ in 0..5 {
                let cfg = ExperimentConfig {
                    backend,
                    function_compute_ns: 100_000,
                    ..Default::default()
                };
                let mut sim = Sim::new();
                let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
                fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
                sim.run_until(SECONDS);
                let sw = Stopwatch::new();
                ClosedLoop::new("aes", n).run(&mut sim, &fs);
                best = best.min(sw.elapsed_ns() as f64 / n as f64);
                events = sim.events_fired();
            }
            println!(
                "{:<11} {best:>8.0} ns wall per simulated invocation, best of 5 ({events} events)",
                backend.name(),
            );
        }
    });

    common::section("perf — PJRT invoke", || {
        match junctiond_repro::runtime::Executor::load(
            &junctiond_repro::runtime::default_artifacts_dir(),
        ) {
            Ok(exec) => {
                let pt = [7u8; 600];
                let key = [1u8; 16];
                let nonce = [2u8; 12];
                let _ = exec.aes600(&pt, &key, &nonce).unwrap();
                common::time_it("aes600 end-to-end (marshal+execute)", 200, || {
                    let _ = exec.aes600(&pt, &key, &nonce).unwrap();
                });
                // rowsum is a near-trivial graph: its time ≈ dispatch floor.
                let x = vec![1.0f32; 64 * 64];
                let _ = x;
                let args = vec![vec![0i32; 600], vec![0; 16], vec![0; 12]];
                common::time_it("aes600 via invoke_i32 (generic path)", 200, || {
                    let _ = exec.invoke_i32("aes600", &args).unwrap();
                });
            }
            Err(e) => println!("skipped (artifacts unavailable: {e})"),
        }
    });

    common::section("perf — rpc framing", || {
        let payload = [0x5Au8; 600];
        let msg = Message::invoke_request(1, "aes600", &payload);
        let mut buf = Vec::new();
        common::time_it("encode_into (reused buffer)", 1_000_000, || {
            msg.encode_into(&mut buf);
        });
        let frame = msg.encode();
        common::time_it("decode", 1_000_000, || {
            let _ = Message::decode(&frame).unwrap();
        });
    });

    common::section("perf — telemetry", || {
        let mut h = LogHistogram::new();
        let mut rng = Rng::new(9);
        common::time_it("LogHistogram::record", 5_000_000, || {
            h.record(rng.below(100_000_000));
        });
        let _ = h.quantile(0.99);
    });

    common::section("perf — fig5 driver wall time", || {
        let sw = Stopwatch::new();
        let _ = ex::fig5_table(100, 1);
        println!("fig5_table(100): {:.2}s wall", sw.elapsed_secs());
    });
}
