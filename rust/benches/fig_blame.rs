//! Bench E15: invocation tracing — per-hop blame decomposition of the
//! tail. Both backends run a 150k-rps open loop of 20 µs bodies with
//! span-per-invocation tracing on; that rate is past the kernel
//! netpath's serial RX drain capacity but far below the 10-core
//! fabric's compute capacity, so where each backend's p99 goes is the
//! paper's argument in one table.
//!
//! Asserts the decomposition's accounting (shares sum to 100%; exemplar
//! hop spans tile the root exactly and sum to the end-to-end latency)
//! and its shape: the kernel backend's p99 is blamed mostly on the
//! netpath + pre-exec scheduler stages, the bypass backend's on
//! execution itself.

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::MILLIS;

fn main() {
    let duration = if common::quick() { 60 * MILLIS } else { 300 * MILLIS };

    common::section("E15 — tail-latency blame decomposition", || {
        let (table, points) = ex::tail_attribution_table(duration, 2);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();
        let find = |b: Backend| points.iter().find(|p| p.backend == b).expect("point");
        let c = find(Backend::Containerd);
        let j = find(Backend::Junctiond);

        // Accounting: each quantile's six hop shares sum to 100% ± 1%.
        let sums_ok = points.iter().all(|p| {
            let s50: f64 = p.report.p50.iter().sum();
            let s99: f64 = p.report.p99.iter().sum();
            (s50 - 1.0).abs() < 0.01 && (s99 - 1.0).abs() < 0.01
        });
        checks.check(
            "blame shares sum to 100% at p50 and p99, both backends",
            sums_ok,
            format!("{} rows", points.len() * 2),
        );

        // Shape: kernel p99 is netpath/scheduler queueing, bypass isn't.
        let c_net = c.report.p99[1] + c.report.p99[2];
        let j_net = j.report.p99[1] + j.report.p99[2];
        checks.check(
            "kernel p99 blame is dominated by nic_rx + pre_exec",
            c_net > 0.5,
            format!("{:.1}%", c_net * 100.0),
        );
        checks.check(
            "bypass p99 carries less net/sched blame than kernel",
            j_net < c_net,
            format!("{:.1}% vs {:.1}%", j_net * 100.0, c_net * 100.0),
        );
        let j_max = j.report.p99.iter().cloned().fold(0.0f64, f64::max);
        checks.check(
            "bypass p99 blame is execution-dominated",
            (j.report.p99[3] - j_max).abs() < 1e-12,
            format!("exec {:.1}% of e2e", j.report.p99[3] * 100.0),
        );

        // Exemplars: the retained slowest traces are internally exact —
        // hop spans tile [submit, done] with no gaps and sum to e2e.
        let tiled = points.iter().all(|p| {
            p.exemplars.iter().all(|tr| {
                let root = &tr.spans[0];
                let kids = tr.root_children();
                let mut cursor = root.start;
                let mut sum = 0;
                for k in &kids {
                    if k.start != cursor {
                        return false;
                    }
                    cursor = k.end;
                    sum += k.duration();
                }
                kids.len() == 5 && cursor == root.end && sum == tr.e2e
            })
        });
        let n_ex: usize = points.iter().map(|p| p.exemplars.len()).sum();
        checks.check(
            "exemplar hop spans tile the root and sum to e2e",
            tiled && n_ex > 0,
            format!("{n_ex} exemplars"),
        );
        checks.finish();
    });
}
