//! Bench E1 / Figure 5: latency distribution of 100 sequential AES-600B
//! invocations, containerd vs junctiond. Asserts the paper's reduction
//! bands (shape, not absolutes — see DESIGN.md §3).
//!
//! Band note: since the compute fabric made interference structural
//! (DESIGN.md §3e), the sampled per-segment noise bursts default off, so
//! this *single-tenant sequential* workload shows the kernel path's
//! per-operation heavy tails only — the reductions sit lower in the band
//! than the paper's co-location-polluted testbed numbers. The isolation
//! headline under real co-location is gated by `fig_isolation.rs` (E14).

mod common;

use junctiond_repro::experiments as ex;

fn main() {
    let n = if common::quick() { 50 } else { 100 };
    common::section("Figure 5 — sequential latency distribution", || {
        let (table, c, j) = ex::fig5_table(n, 1);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();
        let red = |a: u64, b: u64| 1.0 - b as f64 / a as f64;

        let p50 = red(c.gateway.p50, j.gateway.p50);
        checks.check(
            "gateway p50 reduction in band (paper 37.33%)",
            (0.20..0.60).contains(&p50),
            format!("{:.1}%", p50 * 100.0),
        );
        let p99 = red(c.gateway.p99, j.gateway.p99);
        checks.check(
            "gateway p99 reduction in band (paper 63.42%)",
            (0.30..0.90).contains(&p99),
            format!("{:.1}%", p99 * 100.0),
        );
        let e50 = red(c.exec.p50, j.exec.p50);
        checks.check(
            "exec p50 reduction in band (paper 35.3%)",
            (0.15..0.60).contains(&e50),
            format!("{:.1}%", e50 * 100.0),
        );
        let e99 = red(c.exec.p99, j.exec.p99);
        checks.check(
            "exec p99 reduction in band (paper 81%)",
            (0.30..0.95).contains(&e99),
            format!("{:.1}%", e99 * 100.0),
        );
        checks.finish();
    });
}
