//! Bench E3: cold starts. Junction instance init ≈ 3.4 ms (paper §5);
//! containerd cold start is hundreds of ms.

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    let trials = if common::quick() { 20 } else { 100 };
    common::section("Cold starts", || {
        let table = ex::coldstart_table(trials, 5);
        println!("{}", table.to_markdown());
        let get = |r: usize, c: usize| match &table.rows[r][c] {
            Cell::F2(v) => *v,
            _ => unreachable!(),
        };
        let c_init = get(0, 2); // containerd init p50 (ms)
        let j_init = get(2, 2); // junctiond init p50 (ms)
        let mut checks = common::Checks::new();
        checks.check(
            "junction init ≈ 3.4 ms (paper §5)",
            (j_init - 3.4).abs() < 0.5,
            format!("{j_init:.2} ms"),
        );
        checks.check(
            "container cold start ≫ junction (≥ 20×)",
            c_init > 20.0 * j_init,
            format!("{c_init:.0} ms vs {j_init:.2} ms"),
        );
        checks.finish();
    });
}
