//! Bench E5 (§3): polling-core scaling. Junction reserves ONE core for its
//! scheduler regardless of how many functions the server hosts; DPDK-style
//! bypass reserves one polling core per isolated function. Also verifies
//! junctiond's serving latency stays flat as the hosted population grows.

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    let pops: &[u32] =
        if common::quick() { &[1, 16, 256] } else { &[1, 4, 16, 64, 256, 1024, 4096] };
    common::section("Ablation — polling cores vs hosted functions", || {
        let table = ex::ablation_polling_table(pops, 2);
        println!("{}", table.to_markdown());
        let int = |r: usize, c: usize| match &table.rows[r][c] {
            Cell::Int(v) => *v,
            _ => unreachable!(),
        };
        let p99 = |r: usize| match &table.rows[r][5] {
            Cell::NsAsUs(v) => *v,
            _ => unreachable!(),
        };
        let last = table.rows.len() - 1;
        let mut checks = common::Checks::new();
        checks.check(
            "junction polling cores stay at 1 for thousands of functions",
            int(last, 1) == 1,
            format!("{} functions → {} poll core(s)", int(last, 0), int(last, 1)),
        );
        checks.check(
            "dpdk polling cores grow linearly (unhostable past the core count)",
            int(last, 3) == int(last, 0) && int(last, 4) == 0,
            format!("{} poll cores, {} usable", int(last, 3), int(last, 4)),
        );
        // Latency flat within 3× from 1 function to the max population.
        checks.check(
            "junction p99 flat as population grows",
            p99(last) < 3 * p99(0).max(1),
            format!("{}µs → {}µs", p99(0) / 1000, p99(last) / 1000),
        );
        checks.finish();
    });
}
