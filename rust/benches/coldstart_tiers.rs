//! Bench E3b: the tiered provisioning ladder, end to end.
//!
//! Part 1 measures the provisioning latency of every ladder rung (warm
//! pool / snapshot restore / cold boot) for both backends and asserts the
//! shape band: warm < restore < cold on each backend, junctiond beating
//! containerd at every tier, junction cold ≈ 3.4 ms (paper §5).
//!
//! Part 2 replays a bursty multi-tenant trace with keep-alive
//! scale-to-zero, so functions actually walk the ladder, and exports the
//! per-tier serve counts through the Prometheus-style telemetry registry.

mod common;

use std::rc::Rc;

use junctiond_repro::config::{Backend, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::FaasSim;
use junctiond_repro::simcore::{Sim, MILLIS, SECONDS};
use junctiond_repro::telemetry::{Cell, MetricsRegistry};
use junctiond_repro::workload::{replay_with_keepalive, TraceGenerator};

fn main() {
    let trials = if common::quick() { 5 } else { 30 };
    common::section("Cold-start tiers — provisioning latency", || {
        let table = ex::coldstart_tiers_table(trials, 11);
        println!("{}", table.to_markdown());
        // Rows: 0..3 containerd warm/restore/cold, 3..6 junctiond.
        let p50 = |r: usize| match &table.rows[r][2] {
            Cell::F2(v) => *v,
            _ => unreachable!(),
        };
        let (c_warm, c_restore, c_cold) = (p50(0), p50(1), p50(2));
        let (j_warm, j_restore, j_cold) = (p50(3), p50(4), p50(5));
        let mut checks = common::Checks::new();
        checks.check(
            "containerd ladder ordered (warm < restore < cold)",
            c_warm < c_restore && c_restore < c_cold,
            format!("{c_warm:.2} < {c_restore:.2} < {c_cold:.2} ms"),
        );
        checks.check(
            "junctiond ladder ordered (warm < restore < cold)",
            j_warm < j_restore && j_restore < j_cold,
            format!("{j_warm:.3} < {j_restore:.3} < {j_cold:.2} ms"),
        );
        checks.check(
            "junctiond beats containerd at every tier (≥ 10×)",
            j_warm * 10.0 <= c_warm && j_restore * 10.0 <= c_restore && j_cold * 10.0 <= c_cold,
            format!(
                "warm {j_warm:.3}/{c_warm:.2}, restore {j_restore:.3}/{c_restore:.2}, cold {j_cold:.2}/{c_cold:.2} ms"
            ),
        );
        checks.check(
            "junction cold boot ≈ 3.4 ms (paper §5)",
            (j_cold - 3.4).abs() < 0.5,
            format!("{j_cold:.2} ms"),
        );
        checks.finish();
    });

    common::section("Tier mix under a bursty multi-tenant trace", || {
        let duration = if common::quick() { 3 * SECONDS } else { 8 * SECONDS };
        let mut reg = MetricsRegistry::new();
        let mut checks = common::Checks::new();
        for backend in [Backend::Containerd, Backend::Junctiond] {
            let cfg = ex::standard_config(backend, 11);
            let mut sim = Sim::new();
            let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
            // Short keep-alive + pool TTL so the skewed trace's tail
            // functions park, expire, and restore.
            let mut pc = fs.pool_config();
            pc.idle_ttl_ns = 300 * MILLIS;
            fs.set_pool_config(pc);
            fs.start_pool_maintenance(&mut sim, 100 * MILLIS, duration + 12 * SECONDS);
            let events = TraceGenerator::new(16, 100.0, 5).generate(duration);
            let r = replay_with_keepalive(&mut sim, &fs, &events, 16, 100 * MILLIS, |i| {
                format!("fn-{i}")
            });
            println!(
                "{:<11} provisions: warm={:<4} restore={:<4} cold={:<4}   served: warm={:<5} restore={:<5} cold={:<5} (completed {})",
                backend.name(),
                r.provisions[0],
                r.provisions[1],
                r.provisions[2],
                r.tier_served[0],
                r.tier_served[1],
                r.tier_served[2],
                r.completed,
            );
            checks.check(
                &format!("{} tier serve counts cover all completions", backend.name()),
                r.tier_served.iter().sum::<u64>() == r.completed,
                format!("{:?} vs {}", r.tier_served, r.completed),
            );
            checks.check(
                &format!("{} ladder exercised beyond cold boot", backend.name()),
                r.provisions[0] + r.provisions[1] > 0 && r.provisions[2] > 0,
                format!("{:?}", r.provisions),
            );
            fs.export_metrics(&mut reg);
        }
        let text = reg.expose();
        println!("{text}");
        checks.check(
            "per-tier serve counts exported via telemetry",
            text.contains("invocations_served_total")
                && text.contains("tier=\"warm-pool\"")
                && text.contains("tier=\"snapshot-restore\"")
                && text.contains("tier=\"cold-boot\""),
            "prometheus exposition".into(),
        );
        checks.finish();
    });
}
