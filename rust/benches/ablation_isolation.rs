//! Bench E8 (§3 isolation): host-kernel surface exercised per invocation.
//! Junction interposes syscalls in user space and receives packets
//! directly from hardware, so a warm invocation exercises **zero** host
//! syscalls/kernel-stack messages on the request path; containerd's path
//! traps dozens of times.

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    let n = if common::quick() { 30 } else { 100 };
    common::section("Isolation — host-kernel surface per invocation", || {
        let table = ex::isolation_table(n, 1);
        println!("{}", table.to_markdown());
        let f2 = |r: usize, c: usize| match &table.rows[r][c] {
            Cell::F2(v) => *v,
            _ => unreachable!(),
        };
        let mut checks = common::Checks::new();
        checks.check(
            "containerd exercises host kernel heavily",
            f2(0, 1) > 10.0 && f2(0, 2) > 8.0,
            format!("{:.1} syscalls, {:.1} kernel msgs /inv", f2(0, 1), f2(0, 2)),
        );
        checks.check(
            "junctiond request path never enters the host kernel",
            f2(1, 1) == 0.0 && f2(1, 2) == 0.0,
            format!("{:.1} syscalls, {:.1} kernel msgs /inv", f2(1, 1), f2(1, 2)),
        );
        checks.check(
            "junctiond handles syscalls in user space instead",
            f2(1, 4) >= 50.0,
            format!("{:.1} user-space syscalls /inv", f2(1, 4)),
        );
        checks.finish();
    });
}
