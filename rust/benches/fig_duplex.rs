//! Bench E13: the full-duplex network path under load. Two 16-core
//! workers whose responses leave through bounded TX rings (kernel: one
//! qdisc pass + copy + ACK softirq per frame; bypass: polled zero-copy
//! flush bursts) into the front end's own RX NIC.
//!
//! Asserts the duplex model's shape: TX burst amortization sits at
//! exactly 1 on the kernel path and exceeds 1 — growing with load — on
//! the bypass path; the response direction conserves end to end
//! (submitted == completed + dropped, worker TX frames == completions ==
//! gateway RX deliveries); the kernel RX ring sheds at overload; the
//! split kernel send halves (`app_send` + `nic_tx_packet`) cost what the
//! one-shot `send_msg` charges within 5%; and the echo payload sweep
//! moves the kernel TX hop (per-KiB copy) while the zero-copy path stays
//! flat.

mod common;

use std::rc::Rc;

use junctiond_repro::config::{Backend, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::oskernel::KernelCosts;
use junctiond_repro::simcore::{Rng, MICROS, MILLIS};

fn main() {
    let duration = if common::quick() { 150 * MILLIS } else { 400 * MILLIS };

    common::section("E13 — full-duplex netpath load sweep", || {
        let c_rates = ex::duplex_default_containerd_rates();
        let j_rates = ex::duplex_default_junction_rates();
        let (table, points) = ex::duplex_table(2, 16, 600, &c_rates, &j_rates, duration, 5);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();

        // Kernel TX flushes one frame per qdisc pass: pinned at exactly 1.
        let kernel_pinned = points
            .iter()
            .filter(|p| p.backend == Backend::Containerd && p.tx_packets > 0)
            .all(|p| (p.tx_mean_batch - 1.0).abs() < 1e-9);
        checks.check("kernel TX amortization pinned at 1", kernel_pinned, "1.00".into());

        // Bypass TX amortization exceeds 1 and grows with load.
        let j_low = points
            .iter()
            .filter(|p| p.backend == Backend::Junctiond)
            .min_by(|a, b| a.offered_rps.partial_cmp(&b.offered_rps).unwrap())
            .expect("junction points");
        let j_high = points
            .iter()
            .filter(|p| p.backend == Backend::Junctiond)
            .max_by(|a, b| a.offered_rps.partial_cmp(&b.offered_rps).unwrap())
            .expect("junction points");
        checks.check(
            "bypass TX amortization > 1 at the top rate",
            j_high.tx_mean_batch > 1.01,
            format!("{:.3} frames/burst @ {:.0} rps", j_high.tx_mean_batch, j_high.offered_rps),
        );
        checks.check(
            "bypass TX amortization grows with load",
            j_high.tx_mean_batch > j_low.tx_mean_batch,
            format!("{:.3} → {:.3}", j_low.tx_mean_batch, j_high.tx_mean_batch),
        );

        // Duplex conservation at every point: nothing leaks, and the
        // response direction's counters agree with completions.
        let conserved = points.iter().all(|p| {
            p.submitted == p.completed + p.dropped
                && p.tx_packets == p.served
                && p.gw_rx_packets == p.served
        });
        checks.check(
            "submitted == completed + dropped; TX frames == gateway RX == served",
            conserved,
            format!("{} points", points.len()),
        );

        // The kernel RX ring sheds at the overload point.
        let stress = points
            .iter()
            .find(|p| p.backend == Backend::Containerd && p.offered_rps >= 100_000.0);
        checks.check(
            "kernel path sheds at overload",
            stress.map(|p| p.dropped > 0).unwrap_or(false),
            stress.map(|p| format!("dropped {}", p.dropped)).unwrap_or_else(|| "missing".into()),
        );
        checks.finish();
    });

    common::section("E13b — echo payload sweep", || {
        let rate = 1_000.0;
        let payloads = [64u64, 600, 16 << 10, 64 << 10];
        let (table, points) = ex::duplex_payload_sweep_table(2, 16, &payloads, rate, duration, 9);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();
        let find = |b: Backend, pl: u64| {
            points.iter().find(|p| p.backend == b && p.payload_bytes == pl).expect("point")
        };
        let c_small = find(Backend::Containerd, 64);
        let c_big = find(Backend::Containerd, 64 << 10);
        let j_small = find(Backend::Junctiond, 64);
        let j_big = find(Backend::Junctiond, 64 << 10);
        // 64 KiB copied twice (worker TX + gateway RX) at 280 ns/KiB is
        // ~36 µs of copy the kernel path must show on the TX hop.
        checks.check(
            "kernel TX hop pays the per-KiB copy",
            c_big.tx_p50 > c_small.tx_p50 + 20 * MICROS,
            format!("{} µs → {} µs", c_small.tx_p50 / MICROS, c_big.tx_p50 / MICROS),
        );
        checks.check(
            "zero-copy TX hop stays payload-flat",
            j_big.tx_p50 < j_small.tx_p50 + 5 * MICROS,
            format!("{} µs → {} µs", j_small.tx_p50 / MICROS, j_big.tx_p50 / MICROS),
        );
        checks.check(
            "junctiond wins end-to-end at every payload",
            points.iter().filter(|p| p.backend == Backend::Containerd).all(|c| {
                points
                    .iter()
                    .find(|j| {
                        j.backend == Backend::Junctiond && j.payload_bytes == c.payload_bytes
                    })
                    .map(|j| j.p50 < c.p50 && j.p99 < c.p99)
                    .unwrap_or(true)
            }),
            "pointwise".into(),
        );
        checks.finish();
    });

    common::section("send split conservation (app_send + nic_tx_packet ≈ send_msg)", || {
        let n = 20_000u64;
        let mut whole = KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(42));
        let mut split = KernelCosts::new(Rc::new(PlatformConfig::default()), Rng::new(42));
        let a: u64 = (0..n).map(|_| whole.send_msg()).sum();
        let b: u64 = (0..n).map(|_| split.app_send() + split.nic_tx_packet(0)).sum();
        let (am, bm) = (a as f64 / n as f64, b as f64 / n as f64);
        let err = (am - bm).abs() / am;
        let mut checks = common::Checks::new();
        checks.check(
            "split halves cost what send_msg charges (< 5%)",
            err < 0.05,
            format!("{am:.0} ns vs {bm:.0} ns (err {:.2}%)", err * 100.0),
        );
        checks.finish();
    });
}
