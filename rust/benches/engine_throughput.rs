//! Bench: the rebuilt event engine (timer wheel + slab + cancellation)
//! against the seed-shaped `BinaryHeap` engine.
//!
//! Three checks, all CI-gated under `BENCH_QUICK=1`:
//!
//! 1. **Retransmit throughput** — the E11 netpath client's timer pattern
//!    (arm a retransmit/backoff ladder with every send, cancel it when
//!    the response lands) distilled to the engine level and churned over
//!    a **density-scale ballast** of parked idle-TTL timers (up to 1M
//!    pending). The reference heap pays O(log n) pointer-chasing sifts
//!    for every push/pop against that depth *and* carries each cancelled
//!    timer to the top as a tombstone; the wheel inserts/fires in O(1),
//!    leaves the parked timers untouched in its high levels, and skips
//!    cancelled entries with one comparison. Asserts the wheel sustains
//!    **≥5× host events/sec**.
//! 2. **Zero-alloc scheduling** — a steady-state schedule/fire/cancel
//!    microbench with zero-sized closures under a counting global
//!    allocator. Asserts allocations/event ≤ `ALLOC_BUDGET_PER_EVENT`
//!    (budgeted 0: slab slots, wheel buckets and the cascade scratch all
//!    reuse capacity; ZST closures box without allocating).
//! 3. **Determinism under the pipeline** — a small E11 netpath slice run
//!    under both engines must render bit-identical tables (wall-clock on
//!    this slice is reported, not gated: pipeline work dominates it).

mod common;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use junctiond_repro::experiments as ex;
use junctiond_repro::hostclock::Stopwatch;
use junctiond_repro::simcore::{
    set_default_engine, EngineKind, Sim, Time, MICROS, MILLIS, SECONDS,
};

/// Allocation counter wrapped around the system allocator. Counts every
/// alloc/realloc (frees are irrelevant to the budget).
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, n)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// CI budget: steady-state engine scheduling must not allocate.
const ALLOC_BUDGET_PER_EVENT: f64 = 0.01;

/// One flow of the retransmit workload: send → arm a 3-rung backoff
/// ladder → response 10 µs later cancels all three rungs and starts the
/// next send after a 1 µs think gap. This is the netpath client's timer
/// discipline under retransmit-heavy load, minus the pipeline (so the
/// measurement is engine-dominated, as an engine bench must be).
fn flow_hop(sim: &mut Sim, stop_at: Time) {
    if sim.now() >= stop_at {
        return;
    }
    let r1 = sim.after_handle(200 * MICROS, move |sim| flow_hop(sim, stop_at));
    let r2 = sim.after_handle(400 * MICROS, move |sim| flow_hop(sim, stop_at));
    let r3 = sim.after_handle(800 * MICROS, move |sim| flow_hop(sim, stop_at));
    sim.after(10 * MICROS, move |sim| {
        sim.cancel(r1);
        sim.cancel(r2);
        sim.cancel(r3);
        sim.after(MICROS, move |sim| flow_hop(sim, stop_at));
    });
}

/// Run the retransmit workload over a density-scale pending population;
/// returns (events fired, wall seconds). The `ballast` timers model the
/// idle-TTL / keep-alive timers a million-function worker keeps parked:
/// they are scheduled seconds out and never fire inside the measured
/// window (the run stops at `horizon`). The wheel leaves them untouched
/// in its high levels; the seed heap pays ~log(ballast) pointer-chasing
/// on every hot-path push and pop — exactly the "dead weight burns host
/// CPU at the high-load points" failure the rebuild removes.
fn retransmit_workload(kind: EngineKind, flows: usize, ballast: usize, horizon: Time) -> (u64, f64) {
    let mut sim = Sim::with_engine(kind);
    for i in 0..ballast {
        // Parked TTL timers spread over [10 s, 40 s) — within the wheel
        // horizon, far beyond the measured window.
        sim.at(10 * SECONDS + (i as Time) * 30 * MICROS, |_| {});
    }
    for i in 0..flows {
        // Staggered starts so arrivals don't all tie at t=0.
        sim.at(i as Time * 29, move |sim| flow_hop(sim, horizon));
    }
    let sw = Stopwatch::new();
    sim.run_until(horizon);
    (sim.events_fired(), sw.elapsed_secs())
}

/// Steady-state ZST scheduling chain for the allocation microbench: each
/// fire schedules the next hop, plus one extra timer it immediately
/// cancels (the cancel fast path), using only zero-sized closures.
fn zst_chain(sim: &mut Sim) {
    const STOP: Time = 30 * SECONDS;
    if sim.now() >= STOP {
        return;
    }
    // Vary the delta with the clock so inserts exercise several wheel
    // levels without capturing any state.
    let delta = MICROS + (sim.now() % 13) * MICROS;
    let h = sim.after_handle(5 * delta, |sim| zst_chain(sim));
    sim.cancel(h);
    sim.after(delta, |sim| zst_chain(sim));
}

fn main() {
    let quick = common::quick();
    let mut checks = common::Checks::new();

    common::section("engine throughput — E11 retransmit timer workload", || {
        // Active retransmit flows churn (one live event + a three-rung
        // backoff ladder each, ~2 fired events per 11 µs of virtual time)
        // while a density-scale ballast of parked TTL timers sits
        // pending — the E11-at-E12-scale regime.
        let flows = if quick { 2_000 } else { 5_000 };
        let ballast = if quick { 300_000 } else { 1_000_000 };
        let horizon = if quick { 2 * MILLIS } else { 4 * MILLIS };
        // Interleave unmeasured warmups, then take the best of two timed
        // runs per engine: shared CI runners have noisy neighbors, and a
        // single slow outlier on either arm would make the gate flaky.
        retransmit_workload(EngineKind::Wheel, flows / 10, ballast / 10, horizon / 4);
        retransmit_workload(EngineKind::ReferenceHeap, flows / 10, ballast / 10, horizon / 4);
        let best = |kind: EngineKind| {
            let (ev_a, s_a) = retransmit_workload(kind, flows, ballast, horizon);
            let (ev_b, s_b) = retransmit_workload(kind, flows, ballast, horizon);
            assert_eq!(ev_a, ev_b, "identical runs must fire identical event counts");
            (ev_a, s_a.min(s_b))
        };
        let (heap_ev, heap_s) = best(EngineKind::ReferenceHeap);
        let (wheel_ev, wheel_s) = best(EngineKind::Wheel);
        assert_eq!(wheel_ev, heap_ev, "engines must fire identical event counts");
        let wheel_eps = wheel_ev as f64 / wheel_s;
        let heap_eps = heap_ev as f64 / heap_s;
        let ratio = wheel_eps / heap_eps;
        println!(
            "flows={flows} ballast={ballast} events={wheel_ev}  wheel {wheel_eps:.0} ev/s \
             ({wheel_s:.2}s)  seed-heap {heap_eps:.0} ev/s ({heap_s:.2}s)  → {ratio:.1}×"
        );
        checks.check(
            "wheel ≥5× seed-heap events/sec on the retransmit workload",
            ratio >= 5.0,
            format!("{ratio:.2}×"),
        );
    });

    common::section("engine allocations — steady-state scheduling microbench", || {
        let mut sim = Sim::new();
        sim.at(0, |sim| zst_chain(sim));
        // Warm up: size the slab, buckets and scratch buffers.
        sim.run_until(2 * SECONDS);
        let ev0 = sim.events_fired();
        let a0 = ALLOCS.load(Ordering::Relaxed);
        let horizon = if quick { 6 * SECONDS } else { 28 * SECONDS };
        sim.run_until(horizon);
        let events = sim.events_fired() - ev0;
        let allocs = ALLOCS.load(Ordering::Relaxed) - a0;
        let per_event = allocs as f64 / events.max(1) as f64;
        println!("events={events} allocs={allocs} → {per_event:.5} allocs/event");
        checks.check(
            "zero steady-state allocations per scheduled event",
            per_event <= ALLOC_BUDGET_PER_EVENT,
            format!("{per_event:.5} (budget {ALLOC_BUDGET_PER_EVENT})"),
        );
    });

    common::section("determinism — E11 slice identical under both engines", || {
        let rates = [1_000.0, 3_000.0];
        let dur = if quick { 150 * MILLIS } else { 300 * MILLIS };
        let run = || {
            let (t, _) = ex::netpath_table(2, 10, &rates, &rates, dur, 7);
            t.to_markdown()
        };
        let sw0 = Stopwatch::new();
        let wheel = run();
        let wheel_s = sw0.elapsed_secs();
        let prev = set_default_engine(EngineKind::ReferenceHeap);
        let sw1 = Stopwatch::new();
        let heap = run();
        let heap_s = sw1.elapsed_secs();
        set_default_engine(prev);
        // Wall-clock comparison is informational only: on this slice the
        // per-event pipeline work (cost sampling, RefCell state) dominates
        // the engine, and shared CI boxes are too noisy to gate on it.
        println!("wheel {wheel_s:.2}s  seed-heap {heap_s:.2}s  ({:.2}×)", heap_s / wheel_s);
        checks.check(
            "Junction-vs-containerd table bit-identical across engines",
            wheel == heap,
            format!("{} bytes", wheel.len()),
        );
    });

    checks.finish();
}
