//! Bench E6 (§3): junctiond scale-up modes — multi-process per instance
//! (Python-style), max-core raise (Go-style), isolated instances — under a
//! fixed offered load. Higher scale must buy goodput until the offered
//! rate is met.

mod common;

use junctiond_repro::experiments as ex;
use junctiond_repro::telemetry::Cell;

fn main() {
    let rate = if common::quick() { 10_000.0 } else { 20_000.0 };
    common::section("Ablation — junctiond scale-up modes", || {
        let table = ex::ablation_scaleup_table(rate, 4);
        println!("{}", table.to_markdown());
        let goodput = |r: usize| match &table.rows[r][2] {
            Cell::F2(v) => *v,
            _ => unreachable!(),
        };
        // Rows per mode: scales 1,2,4,8 → indices base..base+3.
        let mut checks = common::Checks::new();
        for (mode, base) in [("multi-process", 0), ("max-cores", 4), ("isolated", 8)] {
            let g1 = goodput(base);
            let g8 = goodput(base + 3);
            checks.check(
                &format!("{mode}: scale 8 ≥ scale 1 goodput"),
                g8 >= g1 * 0.98,
                format!("{g1:.0} → {g8:.0} rps"),
            );
            checks.check(
                &format!("{mode}: scale 8 meets offered load"),
                g8 > rate * 0.85,
                format!("{g8:.0} / {rate:.0} rps"),
            );
        }
        checks.finish();
    });
}
