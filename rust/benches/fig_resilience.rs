//! Bench E16: the resilience matrix — seeded fault schedules (instance
//! and worker crashes, gray failure, wire loss, brownout) against the
//! recovery machinery (per-invocation deadlines with cross-replica
//! retry, quantile-derived hedging, health ejection, admission-control
//! brownout).
//!
//! Asserts the fault plane's conservation law on every leg (submitted ==
//! completed + dropped + timed_out, with the full invariant audit clean
//! under an active schedule), and the paper-facing shape: crash recovery
//! restores from snapshot rather than cold-booting — so the bypass
//! backend re-provisions orders of magnitude faster than the kernel
//! backend — and hedged requests defend the gray-failure p99 that
//! health ejection cannot see (nothing fails, everything slows).

mod common;

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::MILLIS;

fn main() {
    let duration = if common::quick() { 60 * MILLIS } else { 300 * MILLIS };

    common::section("E16 — resilience matrix under seeded fault schedules", || {
        let (table, points) = ex::resilience_table(duration, 2);
        println!("{}", table.to_markdown());

        let mut checks = common::Checks::new();
        let find = |b: Backend, s: &str| {
            points.iter().find(|p| p.backend == b && p.scenario == s).expect("point")
        };

        // Conservation: every leg resolves every submission exactly once,
        // and the whole audit tree (cluster, workers, fault plane) is
        // lawful after the run.
        let conserved = points.iter().all(|p| p.conserved() && p.completed > 0);
        checks.check(
            "submitted == completed + dropped + timed_out on every leg",
            conserved,
            format!("{} legs", points.len()),
        );
        let audited = points.iter().all(|p| p.violations.is_empty());
        checks.check(
            "invariant audit clean under every fault schedule",
            audited,
            format!(
                "{} violations",
                points.iter().map(|p| p.violations.len()).sum::<usize>()
            ),
        );

        // Crash recovery: both backends pay a re-provision, and the
        // bypass snapshot restore beats the kernel backend's.
        let jc = find(Backend::Junctiond, "crash+loss");
        let cc = find(Backend::Containerd, "crash+loss");
        checks.check(
            "crashes pay a real re-provision on both backends",
            jc.recovery_ns > 0 && cc.recovery_ns > 0,
            format!("{}µs / {}µs", jc.recovery_ns / 1_000, cc.recovery_ns / 1_000),
        );
        checks.check(
            "bypass crash recovery beats kernel crash recovery",
            jc.recovery_ns < cc.recovery_ns,
            format!(
                "{}µs vs {}µs ({:.1}×)",
                jc.recovery_ns / 1_000,
                cc.recovery_ns / 1_000,
                cc.recovery_ns as f64 / jc.recovery_ns.max(1) as f64
            ),
        );

        // Gray failure: hedging is the only defence (nothing fails, so
        // ejection never triggers) and it must win ≥2× on the p99.
        let ratio = |b: Backend| {
            let off = find(b, "gray").p99 as f64;
            let on = find(b, "gray+hedge").p99.max(1) as f64;
            off / on
        };
        let (rj, rc) = (ratio(Backend::Junctiond), ratio(Backend::Containerd));
        checks.check(
            "hedging wins ≥2× on the bypass gray-failure p99",
            rj >= 2.0,
            format!("{rj:.1}×"),
        );
        checks.check(
            "hedging improves the kernel gray-failure p99",
            rc > 1.0,
            format!("{rc:.1}×"),
        );
        let hedged = points
            .iter()
            .filter(|p| p.scenario == "gray+hedge")
            .all(|p| p.hedge_wins > 0);
        checks.check(
            "hedged duplicates actually win requests",
            hedged,
            format!(
                "{} wins",
                points.iter().map(|p| p.hedge_wins).sum::<u64>()
            ),
        );

        // Brownout: with half the pool down, admission control sheds
        // Batch-class work at the door on both backends.
        let shed = [Backend::Containerd, Backend::Junctiond]
            .iter()
            .all(|&b| find(b, "brownout").shed_batch > 0);
        checks.check(
            "brownout sheds Batch work below the healthy-capacity watermark",
            shed,
            format!(
                "{} shed",
                points.iter().map(|p| p.shed_batch).sum::<u64>()
            ),
        );
        checks.finish();
    });
}
