"""Tiled Pallas matmul kernel vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import mlp, ref

RNG = np.random.default_rng(0xB7)


def randn(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


@pytest.mark.parametrize(
    "m,k,n", [(1, 64, 128), (8, 128, 128), (3, 17, 5), (16, 256, 10), (128, 128, 128)]
)
def test_matmul_bias_matches_jnp(m, k, n):
    x, w, b = randn(m, k), randn(k, n), randn(n)
    got = np.asarray(mlp.matmul_bias(x, w, b))
    want = np.asarray(jnp.dot(x, w) + b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(4, 96, 32), (5, 33, 9)])
def test_matmul_bias_relu(m, k, n):
    x, w, b = randn(m, k), randn(k, n), randn(n)
    got = np.asarray(mlp.matmul_bias(x, w, b, activate=True))
    want = np.maximum(np.asarray(jnp.dot(x, w) + b), 0.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert (got >= 0).all()


@pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (4, 32, 64), (2, 16, 16)])
def test_matmul_tile_size_invariance(bm, bn, bk):
    x, w, b = randn(8, 64), randn(64, 48), randn(48)
    got = np.asarray(mlp.matmul_bias(x, w, b, bm=bm, bn=bn, bk=bk))
    want = np.asarray(jnp.dot(x, w) + b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_mlp_infer_matches_ref():
    x = randn(2, 64)
    w1, b1, w2, b2 = randn(64, 128), randn(128), randn(128, 10), randn(10)
    got = np.asarray(mlp.mlp_infer(x, w1, b1, w2, b2))
    want = np.asarray(ref.mlp_infer_ref(x, w1, b1, w2, b2))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
