"""Stencil (blur) Pallas kernel vs numpy oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import blur, ref

RNG = np.random.default_rng(0xD1)


@pytest.mark.parametrize("h,w,bh", [(64, 64, 16), (64, 64, 64), (33, 17, 8), (5, 5, 16), (16, 128, 4)])
def test_blur_matches_ref(h, w, bh):
    img = RNG.standard_normal((h, w)).astype(np.float32)
    got = np.asarray(blur.blur3x3(img, block_h=bh))
    np.testing.assert_allclose(got, ref.blur3x3_ref(img), rtol=1e-5, atol=1e-5)


def test_blur_constant_image_interior():
    img = np.ones((32, 32), dtype=np.float32)
    got = np.asarray(blur.blur3x3(img))
    # Interior of a constant image stays constant; borders shrink (zero pad).
    np.testing.assert_allclose(got[1:-1, 1:-1], 1.0, rtol=1e-6)
    assert got[0, 0] < 1.0


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(min_value=3, max_value=40),
    w=st.integers(min_value=3, max_value=40),
    bh=st.integers(min_value=1, max_value=20),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_blur_any_shape(h, w, bh, seed):
    img = np.random.default_rng(seed).standard_normal((h, w)).astype(np.float32)
    got = np.asarray(blur.blur3x3(img, block_h=bh))
    np.testing.assert_allclose(got, ref.blur3x3_ref(img), rtol=1e-4, atol=1e-5)
