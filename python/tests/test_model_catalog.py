"""L2 catalog functions: shapes, semantics, and AOT lowering."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(0xC3)


def rand_bytes(*shape):
    return RNG.integers(0, 256, size=shape, dtype=np.int64).astype(np.int32)


def test_catalog_entries_present():
    assert set(model.CATALOG) >= {"aes600", "aes_blocks", "mlp_infer", "rowsum"}


def test_key_expansion_jnp_matches_numpy():
    key = rand_bytes(16)
    got = np.asarray(model.key_expansion_jnp(key))
    want = ref.key_expansion(key)
    np.testing.assert_array_equal(got, want)


def test_ctr_blocks_jnp_matches_numpy():
    nonce = rand_bytes(12)
    got = np.asarray(model.ctr_blocks_jnp(nonce, 38))
    want = ref.ctr_blocks(nonce, 38)
    np.testing.assert_array_equal(got, want)


def test_aes600_matches_ctr_oracle():
    pt, key, nonce = rand_bytes(600), rand_bytes(16), rand_bytes(12)
    (ct,) = model.aes600(pt, key, nonce)
    want = ref.aes_ctr_encrypt_ref(pt, key, nonce)
    np.testing.assert_array_equal(np.asarray(ct), want)


def test_aes600_output_in_byte_range():
    pt, key, nonce = rand_bytes(600), rand_bytes(16), rand_bytes(12)
    (ct,) = model.aes600(pt, key, nonce)
    ct = np.asarray(ct)
    assert ct.min() >= 0 and ct.max() <= 255


def test_mlp_infer_matches_ref_body():
    x = RNG.standard_normal((1, 64)).astype(np.float32)
    (got,) = model.mlp_infer(x)
    (want,) = model.mlp_infer_ref_body(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_rowsum():
    x = RNG.standard_normal((64, 64)).astype(np.float32)
    (got,) = model.rowsum(x)
    np.testing.assert_allclose(np.asarray(got), x.sum(axis=1), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# AOT lowering: every catalog entry must produce loadable HLO text whose
# evaluation (via jax on the lowered stablehlo) matches direct execution.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(model.CATALOG))
def test_lowering_produces_hlo_text(name):
    text = aot.lower_entry(name)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_lowered_aes600_compiles_and_matches():
    """Compile the lowered stablehlo with jax's own PJRT and compare."""
    fn, specs = model.CATALOG["aes600"]
    lowered = jax.jit(fn).lower(*specs)
    compiled = lowered.compile()
    pt, key, nonce = rand_bytes(600), rand_bytes(16), rand_bytes(12)
    (got,) = compiled(jnp.asarray(pt), jnp.asarray(key), jnp.asarray(nonce))
    want = ref.aes_ctr_encrypt_ref(pt, key, nonce)
    np.testing.assert_array_equal(np.asarray(got), want)
