"""Hypothesis property sweeps over the L1 kernels (shapes, keys, payloads).

Per the repo testing strategy: hypothesis owns the Pallas kernels'
shape/dtype space; rust proptest owns the coordinator invariants.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import aes, mlp, ref

SETTINGS = dict(max_examples=25, deadline=None)

byte = st.integers(min_value=0, max_value=255)


@st.composite
def byte_array(draw, shape):
    n = int(np.prod(shape))
    vals = draw(st.lists(byte, min_size=n, max_size=n))
    return np.array(vals, dtype=np.int32).reshape(shape)


@settings(**SETTINGS)
@given(data=st.data(), n=st.integers(min_value=1, max_value=80))
def test_kernel_equals_ref_any_batch(data, n):
    blocks = data.draw(byte_array((n, 16)))
    key = data.draw(byte_array((16,)))
    rks = jnp.asarray(ref.key_expansion(key))
    got = np.asarray(aes.aes_encrypt_blocks(blocks, rks))
    want = np.asarray(ref.aes_encrypt_blocks_ref(blocks, rks))
    np.testing.assert_array_equal(got, want)


@settings(**SETTINGS)
@given(data=st.data(), length=st.integers(min_value=1, max_value=700))
def test_ctr_roundtrip_any_length(data, length):
    pt = data.draw(byte_array((length,)))
    key = data.draw(byte_array((16,)))
    nonce = data.draw(byte_array((12,)))
    n_blocks = (length + 15) // 16
    rks = jnp.asarray(ref.key_expansion(key))
    counters = jnp.asarray(ref.ctr_blocks(nonce, n_blocks))
    ct = np.asarray(aes.aes_ctr_encrypt(pt, rks, counters))
    rt = np.asarray(aes.aes_ctr_encrypt(ct, rks, counters))
    np.testing.assert_array_equal(rt, pt)


@settings(**SETTINGS)
@given(data=st.data())
def test_distinct_keys_give_distinct_ciphertexts(data):
    block = data.draw(byte_array((1, 16)))
    k1 = data.draw(byte_array((16,)))
    k2 = data.draw(byte_array((16,)))
    if k1.tolist() == k2.tolist():
        return
    c1 = np.asarray(aes.aes_encrypt_blocks(block, jnp.asarray(ref.key_expansion(k1))))
    c2 = np.asarray(aes.aes_encrypt_blocks(block, jnp.asarray(ref.key_expansion(k2))))
    # AES is a PRP per key: collisions across random keys are ~2^-128.
    assert c1.tolist() != c2.tolist()


@settings(**SETTINGS)
@given(
    m=st.integers(min_value=1, max_value=16),
    k=st.integers(min_value=1, max_value=64),
    n=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_matmul_any_shape(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((m, k)).astype(np.float32)
    w = rng.standard_normal((k, n)).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    got = np.asarray(mlp.matmul_bias(x, w, b))
    want = x @ w + b
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
