"""Pallas AES kernel vs pure-jnp oracle vs FIPS-197 known answers."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import aes, ref

RNG = np.random.default_rng(0xA5)


def rand_bytes(*shape):
    return RNG.integers(0, 256, size=shape, dtype=np.int64).astype(np.int32)


# ---------------------------------------------------------------------------
# FIPS-197 known-answer tests (appendix A & B vectors)
# ---------------------------------------------------------------------------

FIPS_KEY = np.array(
    [0x2B, 0x7E, 0x15, 0x16, 0x28, 0xAE, 0xD2, 0xA6,
     0xAB, 0xF7, 0x15, 0x88, 0x09, 0xCF, 0x4F, 0x3C], dtype=np.int32)
FIPS_PLAIN = np.array(
    [0x32, 0x43, 0xF6, 0xA8, 0x88, 0x5A, 0x30, 0x8D,
     0x31, 0x31, 0x98, 0xA2, 0xE0, 0x37, 0x07, 0x34], dtype=np.int32)
FIPS_CIPHER = np.array(
    [0x39, 0x25, 0x84, 0x1D, 0x02, 0xDC, 0x09, 0xFB,
     0xDC, 0x11, 0x85, 0x97, 0x19, 0x6A, 0x0B, 0x32], dtype=np.int32)

# FIPS-197 appendix C.1: key 000102...0f, plaintext 00112233...ff.
C1_KEY = np.arange(16, dtype=np.int32)
C1_PLAIN = np.array([(0x11 * i) & 0xFF for i in range(16)], dtype=np.int32)
C1_CIPHER = np.array(
    [0x69, 0xC4, 0xE0, 0xD8, 0x6A, 0x7B, 0x04, 0x30,
     0xD8, 0xCD, 0xB7, 0x80, 0x70, 0xB4, 0xC5, 0x5A], dtype=np.int32)


def test_sbox_known_values():
    # FIPS-197 table 7 spot checks.
    assert ref.SBOX[0x00] == 0x63
    assert ref.SBOX[0x53] == 0xED
    assert ref.SBOX[0xFF] == 0x16
    # S-box is a permutation of 0..255.
    assert sorted(ref.SBOX.tolist()) == list(range(256))


def test_key_expansion_fips_appendix_a():
    rks = ref.key_expansion(FIPS_KEY)
    assert rks.shape == (11, 16)
    assert rks[0].tolist() == FIPS_KEY.tolist()
    # w[43] from FIPS-197 appendix A: b6 63 0c a6
    assert rks[10, 12:].tolist() == [0xB6, 0x63, 0x0C, 0xA6]
    # w[4..7] round key 1: a0 fa fe 17 88 54 2c b1 23 a3 39 39 2a 6c 76 05
    assert rks[1].tolist() == [
        0xA0, 0xFA, 0xFE, 0x17, 0x88, 0x54, 0x2C, 0xB1,
        0x23, 0xA3, 0x39, 0x39, 0x2A, 0x6C, 0x76, 0x05]


@pytest.mark.parametrize(
    "key,plain,cipher",
    [(FIPS_KEY, FIPS_PLAIN, FIPS_CIPHER), (C1_KEY, C1_PLAIN, C1_CIPHER)],
    ids=["appendixB", "appendixC1"],
)
def test_ref_matches_fips(key, plain, cipher):
    rks = jnp.asarray(ref.key_expansion(key))
    out = np.asarray(ref.aes_encrypt_blocks_ref(plain[None, :], rks))
    assert out[0].tolist() == cipher.tolist()


@pytest.mark.parametrize(
    "key,plain,cipher",
    [(FIPS_KEY, FIPS_PLAIN, FIPS_CIPHER), (C1_KEY, C1_PLAIN, C1_CIPHER)],
    ids=["appendixB", "appendixC1"],
)
def test_pallas_kernel_matches_fips(key, plain, cipher):
    rks = jnp.asarray(ref.key_expansion(key))
    out = np.asarray(aes.aes_encrypt_blocks(plain[None, :], rks))
    assert out[0].tolist() == cipher.tolist()


# ---------------------------------------------------------------------------
# Kernel vs oracle over random batches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 2, 3, 16, 38, 255, 256, 300])
def test_kernel_matches_ref_batch(n):
    blocks = rand_bytes(n, 16)
    rks = jnp.asarray(ref.key_expansion(rand_bytes(16)))
    got = np.asarray(aes.aes_encrypt_blocks(blocks, rks))
    want = np.asarray(ref.aes_encrypt_blocks_ref(blocks, rks))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_n", [1, 7, 38, 64, 256])
def test_kernel_tile_size_invariance(block_n):
    """Ciphertext must not depend on the batch tiling."""
    blocks = rand_bytes(90, 16)
    rks = jnp.asarray(ref.key_expansion(rand_bytes(16)))
    got = np.asarray(aes.aes_encrypt_blocks(blocks, rks, block_n=block_n))
    want = np.asarray(ref.aes_encrypt_blocks_ref(blocks, rks))
    np.testing.assert_array_equal(got, want)


def test_ctr_mode_roundtrip():
    """CTR encryption is its own inverse."""
    key, nonce = rand_bytes(16), rand_bytes(12)
    plaintext = rand_bytes(600)
    rks = jnp.asarray(ref.key_expansion(key))
    counters = jnp.asarray(ref.ctr_blocks(nonce, 38))
    ct = np.asarray(aes.aes_ctr_encrypt(plaintext, rks, counters))
    rt = np.asarray(aes.aes_ctr_encrypt(ct, rks, counters))
    np.testing.assert_array_equal(rt, plaintext)
    assert (ct != plaintext).any()


def test_ctr_matches_ref():
    key, nonce = rand_bytes(16), rand_bytes(12)
    plaintext = rand_bytes(600)
    rks = jnp.asarray(ref.key_expansion(key))
    counters = jnp.asarray(ref.ctr_blocks(nonce, 38))
    got = np.asarray(aes.aes_ctr_encrypt(plaintext, rks, counters))
    want = ref.aes_ctr_encrypt_ref(plaintext, key, nonce)
    np.testing.assert_array_equal(got, want)
