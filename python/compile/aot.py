"""AOT: lower every catalog entry to HLO *text* artifacts.

This is the one-shot compile path (``make artifacts``).  Python never runs
on the request path: the Rust runtime loads ``artifacts/<name>.hlo.txt`` via
``HloModuleProto::from_text_file`` and executes through the PJRT CPU client.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Every catalog function returns a tuple and is lowered with
``return_tuple=True``; the Rust side unwraps with ``to_tuple1()``.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import CATALOG


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text.

    ``print_large_constants=True`` is load-bearing: the default HLO printer
    elides big constants as ``constant({...})`` and the old text parser
    silently fills them with pattern data — the AES S-box would round-trip
    as garbage (caught by the Rust-vs-RustCrypto cross-check).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_entry(name: str) -> str:
    fn, arg_specs = CATALOG[name]
    lowered = jax.jit(fn).lower(*arg_specs)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument(
        "--only", nargs="*", default=None, help="subset of catalog entries to lower"
    )
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only if args.only else list(CATALOG)
    manifest = {}
    for name in names:
        text = lower_entry(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        _, arg_specs = CATALOG[name]
        manifest[name] = {
            "artifact": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in arg_specs
            ],
        }
        print(f"lowered {name}: {len(text)} chars -> {path}")

    # Manifests consumed by the Rust runtime's function registry. JSON for
    # humans/tools, INI for the Rust loader (no serde in the offline
    # vendored crate set).
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    with open(os.path.join(args.out_dir, "manifest.ini"), "w") as f:
        for name, entry in manifest.items():
            f.write(f"[{name}]\n")
            f.write(f"artifact = {entry['artifact']}\n")
            args_sig = ";".join(
                f"{a['dtype']}:{','.join(str(d) for d in a['shape'])}"
                for a in entry["args"]
            )
            f.write(f"args = {args_sig}\n\n")
    print(f"wrote manifest for {len(manifest)} entries")


if __name__ == "__main__":
    main()
