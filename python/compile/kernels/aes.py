"""L1: AES-128 block-encryption as a Pallas kernel.

The paper's benchmark function (vSwarm ``aes``) encrypts a 600-byte input.
Its compute hot-spot is the AES round pipeline over a batch of 16-byte
blocks; this module expresses that pipeline as a single Pallas kernel so the
whole 10-round dataflow stays resident in VMEM.

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation):

* The batch of states is one ``(N, 16)`` int32 tile — a single BlockSpec
  block.  For the paper's workload N = 38 blocks (608 B padded), i.e. the
  working set is ~2.4 KB: trivially VMEM-resident, so there is no HBM↔VMEM
  schedule to pipeline; one grid step suffices.  For large payloads the
  grid tiles the batch dimension in ``BLOCK_N``-block chunks.
* AES has no matmul structure → this is VPU (vector-lane) work, not MXU.
  SubBytes is a vectorized 256-entry gather; MixColumns is shifts/XORs over
  int32 lanes; ShiftRows is a static lane permutation.
* All 10 rounds are unrolled *inside* the kernel, so intermediate round
  state never leaves VMEM (the analogue of keeping GPU state in registers /
  shared memory).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the same
artifact runs under the rust PJRT CPU client.  Correctness versus
``ref.aes_encrypt_blocks_ref`` is exact (integer ops).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Tile size (in AES blocks) along the batch dimension.  38-block payloads fit
# in a single tile; the value is a multiple of 8 to keep lanes full when the
# batch is large.
BLOCK_N = 256


def _xtime(a):
    """GF(2^8) multiply-by-2 on int32 lanes (AES polynomial 0x11B)."""
    return ((a << 1) & 0xFF) ^ (((a >> 7) & 1) * 0x1B)


def _aes_round_state(state, sbox, perm):
    """SubBytes + ShiftRows (+ caller applies MixColumns/AddRoundKey)."""
    state = jnp.take(sbox, state, axis=0)  # SubBytes: vectorized gather
    state = jnp.take(state, perm, axis=1)  # ShiftRows: static lane permutation
    return state


def _mix_columns(state):
    """MixColumns over (n, 16) column-major states, int32 lanes."""
    s = state.reshape(-1, 4, 4)  # [n, col, row]
    a0, a1, a2, a3 = s[:, :, 0], s[:, :, 1], s[:, :, 2], s[:, :, 3]
    x0, x1, x2, x3 = _xtime(a0), _xtime(a1), _xtime(a2), _xtime(a3)
    b0 = x0 ^ (x1 ^ a1) ^ a2 ^ a3
    b1 = a0 ^ x1 ^ (x2 ^ a2) ^ a3
    b2 = a0 ^ a1 ^ x2 ^ (x3 ^ a3)
    b3 = (x0 ^ a0) ^ a1 ^ a2 ^ x3
    return jnp.stack([b0, b1, b2, b3], axis=2).reshape(-1, 16)


def _aes_kernel(blocks_ref, rk_ref, sbox_ref, perm_ref, out_ref):
    """Pallas kernel body: encrypt one (BLOCK_N, 16) tile of AES states.

    ``rk_ref``: (11, 16) round keys; ``sbox_ref``: (256,) S-box;
    ``perm_ref``: (16,) ShiftRows lane permutation.  All three are small
    enough to be replicated into VMEM for every grid step.
    """
    state = blocks_ref[...]
    rks = rk_ref[...]
    sbox = sbox_ref[...]
    perm = perm_ref[...]

    state = state ^ rks[0][None, :]
    # Rounds 1..9 unrolled: the whole round dataflow stays in VMEM.
    for rnd in range(1, 10):
        state = _aes_round_state(state, sbox, perm)
        state = _mix_columns(state)
        state = state ^ rks[rnd][None, :]
    # Final round: no MixColumns.
    state = _aes_round_state(state, sbox, perm)
    state = state ^ rks[10][None, :]
    out_ref[...] = state


@functools.partial(jax.jit, static_argnames=("block_n",))
def aes_encrypt_blocks(blocks, round_keys, sbox=None, *, block_n: int = BLOCK_N):
    """Encrypt a batch of AES states with the Pallas kernel.

    Args:
      blocks: (N, 16) int32 states (byte values in [0, 255]).
      round_keys: (11, 16) int32 expanded key (``ref.key_expansion``).
      sbox: optional (256,) int32 S-box (defaults to ``ref.SBOX``).
      block_n: batch-tile size; the grid covers ceil(N / block_n) steps.

    Returns (N, 16) int32 ciphertext states, bit-identical to
    ``ref.aes_encrypt_blocks_ref``.
    """
    if sbox is None:
        sbox = jnp.asarray(ref.SBOX)
    blocks = jnp.asarray(blocks, dtype=jnp.int32)
    round_keys = jnp.asarray(round_keys, dtype=jnp.int32)
    n = blocks.shape[0]

    # Pad the batch to a whole number of tiles; strip afterwards.
    tile = min(block_n, max(n, 1))
    n_pad = (tile - n % tile) % tile
    padded = jnp.pad(blocks, ((0, n_pad), (0, 0)))
    grid = (padded.shape[0] // tile,)

    out = pl.pallas_call(
        _aes_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 16), lambda i: (i, 0)),
            pl.BlockSpec((11, 16), lambda i: (0, 0)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((16,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, 16), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, jnp.int32),
        interpret=True,
    )(padded, round_keys, sbox, jnp.asarray(ref.SHIFT_ROWS_PERM))
    return out[:n]


def aes_ctr_encrypt(plaintext, round_keys, counters):
    """AES-128-CTR: encrypt ``counters`` with the kernel, XOR into payload.

    Args:
      plaintext: (L,) int32 byte payload.
      round_keys: (11, 16) int32 expanded key.
      counters: (ceil(L/16), 16) int32 counter blocks (``ref.ctr_blocks``).
    """
    plaintext = jnp.asarray(plaintext, dtype=jnp.int32)
    keystream = aes_encrypt_blocks(counters, round_keys).reshape(-1)
    return plaintext ^ keystream[: plaintext.shape[0]]
