"""L1: tiled matmul kernel used by the ``mlp_infer`` catalog function.

Where the AES kernel is VPU-shaped (byte lanes, gathers, XORs), this kernel
is the MXU-shaped counterpart: a K-accumulating tiled matmul with fused bias
and optional ReLU, demonstrating the standard BlockSpec HBM↔VMEM tiling
pattern.  ``interpret=True`` as everywhere (CPU PJRT cannot run Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, activate: bool, n_k: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost.

    The output tile is used as the accumulator across the K grid dimension
    (revisiting o_ref is the canonical Pallas accumulation pattern).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...][None, :]
        if activate:
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "activate"))
def matmul_bias(x, w, b, *, bm: int = 8, bn: int = 128, bk: int = 128, activate: bool = False):
    """Compute ``act(x @ w + b)`` with a tiled Pallas kernel.

    Tile sizes default to MXU-friendly shapes (lane dim 128); dimensions are
    padded up to tile multiples and the pad is stripped from the result.
    """
    x = jnp.asarray(x, dtype=jnp.float32)
    w = jnp.asarray(w, dtype=jnp.float32)
    b = jnp.asarray(b, dtype=jnp.float32)
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)

    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)

    def pad_to(a, axis, mult):
        pad = (mult - a.shape[axis] % mult) % mult
        if pad == 0:
            return a
        widths = [(0, 0)] * a.ndim
        widths[axis] = (0, pad)
        return jnp.pad(a, widths)

    xp = pad_to(pad_to(x, 0, bm_), 1, bk_)
    wp = pad_to(pad_to(w, 0, bk_), 1, bn_)
    bp = pad_to(b, 0, bn_)
    gm, gn, gk = xp.shape[0] // bm_, wp.shape[1] // bn_, xp.shape[1] // bk_

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, activate=activate, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn_,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def mlp_infer(x, w1, b1, w2, b2):
    """Two-layer MLP built from the tiled kernel: relu(x@w1+b1) @ w2 + b2."""
    h = matmul_bias(x, w1, b1, activate=True)
    return matmul_bias(h, w2, b2, activate=False)
