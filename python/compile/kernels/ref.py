"""Pure-jnp reference oracles (L1 correctness ground truth).

This module is the *specification* against which the Pallas kernels in
``aes.py`` / ``mlp.py`` are validated (pytest ``assert_allclose`` /
exact-equality for integer AES).  It is deliberately written for clarity,
not speed: plain ``jnp`` ops, no pallas, no fori_loop tricks.

AES-128 follows FIPS-197.  The state layout convention used throughout the
repo is the standard *column-major* AES state: flat byte index
``i = row + 4*col`` for ``row, col in 0..4``.  All byte values are carried
as int32 in ``[0, 255]`` (the ``xla`` crate marshals i32 literals; u8 is
not in its NativeType set).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# AES tables (FIPS-197 §5.1.1)
# ---------------------------------------------------------------------------


def _build_sbox() -> np.ndarray:
    """Construct the AES S-box from GF(2^8) inversion + affine transform.

    Building it (rather than pasting the table) gives an independent check:
    the hardcoded KAT vectors in the tests would fail loudly on any slip.
    """
    inv = np.zeros(256, dtype=np.int64)

    def gf_mul(a: int, b: int) -> int:
        p = 0
        for _ in range(8):
            if b & 1:
                p ^= a
            hi = a & 0x80
            a = (a << 1) & 0xFF
            if hi:
                a ^= 0x1B
            b >>= 1
        return p

    for a in range(1, 256):
        for b in range(1, 256):
            if gf_mul(a, b) == 1:
                inv[a] = b
                break

    def rotl(v: int, n: int) -> int:
        return ((v << n) | (v >> (8 - n))) & 0xFF

    sbox = np.zeros(256, dtype=np.int64)
    for x in range(256):
        y = inv[x]
        # Affine transform over GF(2).
        sbox[x] = y ^ rotl(y, 1) ^ rotl(y, 2) ^ rotl(y, 3) ^ rotl(y, 4) ^ 0x63
    return sbox.astype(np.int32)


SBOX = _build_sbox()

# Round constants for key expansion (first byte of each rcon word).
RCON = np.array([0x01, 0x02, 0x04, 0x08, 0x10, 0x20, 0x40, 0x80, 0x1B, 0x36], dtype=np.int32)

# ShiftRows as a flat permutation over the column-major state:
# new[r + 4c] = old[r + 4*((c + r) % 4)]
SHIFT_ROWS_PERM = np.array(
    [r + 4 * ((c + r) % 4) for c in range(4) for r in range(4)], dtype=np.int32
)


# ---------------------------------------------------------------------------
# GF(2^8) helpers (vectorized over int32 lanes)
# ---------------------------------------------------------------------------


def xtime(a):
    """Multiply by x (i.e. by 2) in GF(2^8) with the AES polynomial 0x11B."""
    a = jnp.asarray(a)
    shifted = (a << 1) & 0xFF
    overflow = (a >> 7) & 1
    return shifted ^ (overflow * 0x1B)


def gf_mul2(a):
    return xtime(a)


def gf_mul3(a):
    return xtime(a) ^ a


# ---------------------------------------------------------------------------
# Key expansion (FIPS-197 §5.2) — runs at L2 (outside the kernel)
# ---------------------------------------------------------------------------


def key_expansion(key: np.ndarray) -> np.ndarray:
    """Expand a 16-byte key into 11 round keys, shape (11, 16) int32.

    Pure numpy: key expansion happens once per function deployment, never on
    the per-request path, so there is no reason to trace it.
    """
    key = np.asarray(key, dtype=np.int32).reshape(16)
    words = [key[4 * i : 4 * i + 4].copy() for i in range(4)]
    for i in range(4, 44):
        temp = words[i - 1].copy()
        if i % 4 == 0:
            temp = np.roll(temp, -1)  # RotWord
            temp = SBOX[temp]  # SubWord
            temp[0] ^= RCON[i // 4 - 1]
        words.append(words[i - 4] ^ temp)
    return np.stack(words).reshape(11, 16).astype(np.int32)


# ---------------------------------------------------------------------------
# Block encryption (the oracle the Pallas kernel must match bit-for-bit)
# ---------------------------------------------------------------------------


def sub_bytes(state, sbox):
    return jnp.take(sbox, state, axis=0)


def shift_rows(state):
    return state[:, SHIFT_ROWS_PERM]


def mix_columns(state):
    """MixColumns on (N, 16) column-major states."""
    s = state.reshape(-1, 4, 4)  # [n, col, row]
    a = [s[:, :, r] for r in range(4)]
    b = [
        gf_mul2(a[0]) ^ gf_mul3(a[1]) ^ a[2] ^ a[3],
        a[0] ^ gf_mul2(a[1]) ^ gf_mul3(a[2]) ^ a[3],
        a[0] ^ a[1] ^ gf_mul2(a[2]) ^ gf_mul3(a[3]),
        gf_mul3(a[0]) ^ a[1] ^ a[2] ^ gf_mul2(a[3]),
    ]
    return jnp.stack(b, axis=2).reshape(-1, 16)


def add_round_key(state, rk):
    return state ^ rk[None, :]


def aes_encrypt_blocks_ref(blocks, round_keys, sbox=None):
    """Encrypt (N, 16) int32 blocks with (11, 16) int32 round keys (ECB)."""
    if sbox is None:
        sbox = jnp.asarray(SBOX)
    state = jnp.asarray(blocks, dtype=jnp.int32)
    state = add_round_key(state, round_keys[0])
    for rnd in range(1, 10):
        state = sub_bytes(state, sbox)
        state = shift_rows(state)
        state = mix_columns(state)
        state = add_round_key(state, round_keys[rnd])
    state = sub_bytes(state, sbox)
    state = shift_rows(state)
    state = add_round_key(state, round_keys[10])
    return state


def ctr_blocks(nonce: np.ndarray, n_blocks: int) -> np.ndarray:
    """Build CTR-mode counter blocks: nonce (12 bytes) || 32-bit BE counter."""
    nonce = np.asarray(nonce, dtype=np.int32).reshape(12)
    out = np.zeros((n_blocks, 16), dtype=np.int32)
    out[:, :12] = nonce[None, :]
    ctr = np.arange(n_blocks, dtype=np.int64)
    for i in range(4):
        out[:, 12 + i] = ((ctr >> (8 * (3 - i))) & 0xFF).astype(np.int32)
    return out


def aes_ctr_encrypt_ref(plaintext, key, nonce):
    """AES-128-CTR over a flat byte payload (int32 values in [0,255]).

    End-to-end oracle for the ``aes600`` catalog entry (the paper's 600-byte
    vSwarm AES function).  Ciphertext has the same length as the plaintext.
    """
    plaintext = np.asarray(plaintext, dtype=np.int32).reshape(-1)
    n = plaintext.shape[0]
    n_blocks = (n + 15) // 16
    rks = key_expansion(key)
    counters = ctr_blocks(nonce, n_blocks)
    keystream = np.asarray(aes_encrypt_blocks_ref(counters, jnp.asarray(rks)))
    keystream = keystream.reshape(-1)[:n]
    return plaintext ^ keystream


# ---------------------------------------------------------------------------
# MLP / analytics references (other vSwarm-style catalog entries)
# ---------------------------------------------------------------------------


def mlp_infer_ref(x, w1, b1, w2, b2):
    """Two-layer MLP: relu(x @ w1 + b1) @ w2 + b2."""
    h = jnp.maximum(jnp.dot(x, w1) + b1, 0.0)
    return jnp.dot(h, w2) + b2


def rowsum_ref(x):
    """Row-sum analytics function (pure-L2 path, no pallas)."""
    return jnp.sum(x, axis=1)


def blur3x3_ref(img):
    """3x3 zero-padded box blur (numpy oracle for the stencil kernel)."""
    import numpy as _np
    img = _np.asarray(img, dtype=_np.float32)
    p = _np.pad(img, 1)
    h, w = img.shape
    out = _np.zeros_like(img)
    for dy in range(3):
        for dx in range(3):
            out += p[dy : dy + h, dx : dx + w]
    return out / 9.0
