"""L1: 3×3 box-blur stencil as a Pallas kernel.

Stands in for vSwarm's image-processing functions (thumbnailing, filters).
A stencil is the third kernel *shape* in the repo next to the VPU-byte-wise
AES and the MXU matmul: neighborhood reads with halo handling.

TPU adaptation: at these image sizes the whole padded image is
VMEM-resident (64×64 f32 ≈ 16 KB ≪ 16 MB), so the grid tiles only the
*output* in row bands and each step dynamic-slices its input band (with a
1-row halo) from the resident image; the 3×3 average is 9 shifted adds on
the VPU. ``interpret=True`` as everywhere in this repo.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


@functools.partial(jax.jit, static_argnames=("block_h",))
def blur3x3(img, *, block_h: int = 16):
    """3×3 box blur with zero boundary, via a row-band Pallas kernel.

    Args:
      img: (H, W) float32 image.
    Returns the blurred (H, W) image (average of the 3×3 neighborhood with
    zero padding at the borders).
    """
    img = jnp.asarray(img, dtype=jnp.float32)
    h, w = img.shape

    bh = min(block_h, h)
    n_bands = -(-h // bh)
    padded_h = n_bands * bh
    # One halo row top and bottom (+ tail padding up to whole bands).
    img_p = jnp.pad(img, ((1, 1 + padded_h - h), (0, 0)))

    def kernel(img_ref, out_ref, *, bh=bh, w=w):
        i = pl.program_id(0)
        band = jax.lax.dynamic_slice(img_ref[...], (i * bh, 0), (bh + 2, w))
        pc = jnp.pad(band, ((0, 0), (1, 1)))  # column halo
        acc = jnp.zeros((bh, w), dtype=jnp.float32)
        for dy in range(3):
            for dx in range(3):
                acc = acc + pc[dy : dy + bh, dx : dx + w]
        out_ref[...] = acc / 9.0

    out = pl.pallas_call(
        kernel,
        grid=(n_bands,),
        in_specs=[pl.BlockSpec(img_p.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bh, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((padded_h, w), jnp.float32),
        interpret=True,
    )(img_p)
    return out[:h, :]
