"""L2: the FaaS function catalog as JAX compute graphs.

Each entry here is a *function body* that the Rust FaaS runtime executes on
the request path (after AOT lowering to HLO by ``aot.py``).  They mirror the
vSwarm functions the paper evaluates with:

* ``aes600``   — the headline workload: AES-128-CTR encryption of a 600-byte
                 payload (paper §5 "Methodology").  Calls the Pallas AES
                 kernel (L1) for the block pipeline; key expansion and
                 counter-block construction are traced into the same graph so
                 the artifact is self-contained: (plaintext, key, nonce) →
                 ciphertext.
* ``aes_blocks`` — kernel-only artifact (fixed 256-block batch) used by the
                 perf microbenches to isolate kernel cost from marshaling.
* ``mlp_infer`` — a small ML-inference function (two-layer MLP over the
                 tiled Pallas matmul kernel), standing in for vSwarm's model
                 -serving workloads.
* ``rowsum``   — a trivial analytics function on the pure-jnp path (no
                 Pallas), exercising the L2-only lowering path.

All byte-valued tensors are int32 in [0, 255]: the Rust ``xla`` crate's
NativeType set has no u8, and widening costs nothing at these sizes.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import aes as aes_kernel
from .kernels import blur as blur_kernel
from .kernels import mlp as mlp_kernel
from .kernels import ref

PAYLOAD_BYTES = 600  # the paper's 600-byte AES input
N_BLOCKS = (PAYLOAD_BYTES + 15) // 16  # 38


# ---------------------------------------------------------------------------
# In-graph key expansion + counter construction (static shapes, unrolled)
# ---------------------------------------------------------------------------


def key_expansion_jnp(key):
    """FIPS-197 key expansion traced into the graph ((16,) → (11, 16)).

    The loop unrolls at trace time (44 words); the S-box lookups become
    gathers.  Running it in-graph keeps the artifact self-contained — the
    Rust side passes the raw 16-byte key, not pre-expanded round keys.
    """
    sbox = jnp.asarray(ref.SBOX)
    rcon = jnp.asarray(ref.RCON)
    key = jnp.asarray(key, dtype=jnp.int32).reshape(16)
    words = [key[4 * i : 4 * i + 4] for i in range(4)]
    for i in range(4, 44):
        temp = words[i - 1]
        if i % 4 == 0:
            temp = jnp.roll(temp, -1)
            temp = jnp.take(sbox, temp, axis=0)
            temp = temp.at[0].set(temp[0] ^ rcon[i // 4 - 1])
        words.append(words[i - 4] ^ temp)
    return jnp.stack(words).reshape(11, 16)


def ctr_blocks_jnp(nonce, n_blocks: int):
    """Counter blocks nonce||BE32(i) for i in 0..n_blocks ((12,) → (n, 16))."""
    nonce = jnp.asarray(nonce, dtype=jnp.int32).reshape(12)
    ctr = jnp.arange(n_blocks, dtype=jnp.int32)
    be = jnp.stack([(ctr >> (8 * (3 - i))) & 0xFF for i in range(4)], axis=1)
    return jnp.concatenate(
        [jnp.broadcast_to(nonce[None, :], (n_blocks, 12)), be], axis=1
    )


# ---------------------------------------------------------------------------
# Catalog function bodies
# ---------------------------------------------------------------------------


def aes600(plaintext, key, nonce):
    """AES-128-CTR encrypt a 600-byte payload.  Returns (600,) ciphertext."""
    rks = key_expansion_jnp(key)
    counters = ctr_blocks_jnp(nonce, N_BLOCKS)
    return (aes_kernel.aes_ctr_encrypt(plaintext, rks, counters),)


def aes_blocks(blocks, round_keys):
    """Kernel-only ECB batch encrypt ((256,16),(11,16)) → (256,16)."""
    return (aes_kernel.aes_encrypt_blocks(blocks, round_keys),)


def _mlp_weights(in_dim=64, hidden=128, out_dim=10, seed=7):
    """Deterministic baked weights for the inference function."""
    rng = np.random.default_rng(seed)
    w1 = rng.standard_normal((in_dim, hidden), dtype=np.float32) / np.sqrt(in_dim)
    b1 = np.zeros(hidden, dtype=np.float32)
    w2 = rng.standard_normal((hidden, out_dim), dtype=np.float32) / np.sqrt(hidden)
    b2 = np.zeros(out_dim, dtype=np.float32)
    return w1, b1, w2, b2


MLP_WEIGHTS = _mlp_weights()


def mlp_infer(x):
    """Two-layer MLP inference over baked weights ((1,64) → (1,10))."""
    w1, b1, w2, b2 = (jnp.asarray(w) for w in MLP_WEIGHTS)
    return (mlp_kernel.mlp_infer(x, w1, b1, w2, b2),)


def mlp_infer_ref_body(x):
    """Same MLP on the pure-jnp path (oracle for the artifact test)."""
    w1, b1, w2, b2 = (jnp.asarray(w) for w in MLP_WEIGHTS)
    return (ref.mlp_infer_ref(x, w1, b1, w2, b2),)


def rowsum(x):
    """Row sums of a (64, 64) matrix — L2-only path, no Pallas."""
    return (ref.rowsum_ref(x),)


def blur(img):
    """3×3 box blur of a (64, 64) image — vSwarm image-processing stand-in."""
    return (blur_kernel.blur3x3(img),)


# ---------------------------------------------------------------------------
# Catalog: name → (callable, example argument specs)
# ---------------------------------------------------------------------------

I32 = jnp.int32
F32 = jnp.float32


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


CATALOG = {
    "aes600": (aes600, [spec((PAYLOAD_BYTES,), I32), spec((16,), I32), spec((12,), I32)]),
    "aes_blocks": (aes_blocks, [spec((256, 16), I32), spec((11, 16), I32)]),
    "mlp_infer": (mlp_infer, [spec((1, 64), F32)]),
    "rowsum": (rowsum, [spec((64, 64), F32)]),
    "blur": (blur, [spec((64, 64), F32)]),
}
