//! Figure 5 reproduction: latency distribution of 100 sequential AES-600B
//! invocations, containerd vs junctiond, gateway-observed + function-exec.
//!
//! Prints the comparison table (with the paper's claimed reductions) and
//! a 10-point CDF sketch per backend.
//!
//! ```sh
//! cargo run --release --example latency_distribution
//! ```

use junctiond_repro::experiments as ex;

fn sketch_cdf(label: &str, cdf: &[(u64, f64)]) {
    println!("  {label} CDF:");
    for q in [0.10, 0.25, 0.50, 0.75, 0.90, 0.95, 0.99, 1.00] {
        let idx = ((q * cdf.len() as f64).ceil() as usize).clamp(1, cdf.len()) - 1;
        let (v, _) = cdf[idx];
        let bar = "#".repeat((v / 20_000).min(80) as usize);
        println!("    p{:<4} {:>9.2} µs |{}", (q * 100.0) as u32, v as f64 / 1e3, bar);
    }
}

fn main() {
    let (table, c, j) = ex::fig5_table(100, 1);
    println!("{}", table.to_markdown());
    println!("containerd:");
    sketch_cdf("gateway", &c.gateway_cdf);
    println!("junctiond:");
    sketch_cdf("gateway", &j.gateway_cdf);
    println!(
        "\npaper: median −37.33%, P99 −63.42% (gateway); median −35.3%, P99 −81% (exec)"
    );
}
