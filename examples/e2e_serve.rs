//! End-to-end REAL serving demo (no simulation): the faasd topology as
//! actual threads — client → gateway → provider → worker — with the worker
//! executing the AOT-compiled AES-600B artifact through PJRT on every
//! request.
//!
//! Two transports, same components:
//! * `kernel` — loopback TCP, every hop through the host kernel;
//! * `bypass` — polled shared-memory rings, hops never enter the kernel.
//!
//! Reports latency percentiles and throughput for both, i.e. the paper's
//! experiment shrunk onto one machine with real compute.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```

use junctiond_repro::runtime::default_artifacts_dir;
use junctiond_repro::server::{run_pipeline, ServeMode};
use junctiond_repro::telemetry::Samples;

fn run(mode: ServeMode, n: usize) -> anyhow::Result<Samples> {
    let mut h = run_pipeline(mode, default_artifacts_dir())?;
    let payload = [0xA5u8; 600];
    for _ in 0..20 {
        h.invoke_aes600(&payload)?; // warmup (PJRT compile cache, TCP slow start)
    }
    let mut lat = Samples::with_capacity(n);
    let t0 = std::time::Instant::now();
    for _ in 0..n {
        let s = std::time::Instant::now();
        h.invoke_aes600(&payload)?;
        lat.record(s.elapsed().as_nanos() as u64);
    }
    let wall = t0.elapsed();
    h.shutdown()?;
    println!(
        "{:>7}: {}  | throughput {:.0} req/s",
        mode.name(),
        lat.clone().summary().fmt_us(),
        n as f64 / wall.as_secs_f64()
    );
    Ok(lat)
}

fn main() -> anyhow::Result<()> {
    let n = 300;
    println!("real serving, {n} sequential AES-600B invocations (PJRT compute):");
    let mut kernel = run(ServeMode::Kernel, n)?;
    let mut bypass = run(ServeMode::Bypass, n)?;
    let p50k = kernel.quantile(0.5) as f64;
    let p50b = bypass.quantile(0.5) as f64;
    let p99k = kernel.quantile(0.99) as f64;
    let p99b = bypass.quantile(0.99) as f64;
    println!(
        "bypass vs kernel: median {:.1}% lower, p99 {:.1}% lower",
        (1.0 - p50b / p50k) * 100.0,
        (1.0 - p99b / p99k) * 100.0
    );
    println!("(three hops of kernel TCP vs three hops of polled shared memory, same worker)");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores <= 2 {
        println!(
            "NOTE: {cores}-core host — polling cannot be overlapped with the producer, so \
             expect rough parity here; the paper's gap needs cores to poll on \
             (the DES experiments carry the multi-core result)."
        );
    }
    Ok(())
}
