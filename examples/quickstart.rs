//! Quickstart: deploy the paper's AES function on both backends, invoke it
//! a few times through the full faasd pipeline, and print the latencies —
//! plus one *real* (non-simulated) invocation through the PJRT executor to
//! prove the artifact path works end to end.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::cell::RefCell;
use std::rc::Rc;

use junctiond_repro::config::{Backend, ExperimentConfig, PlatformConfig};
use junctiond_repro::faas::{FaasSim, FunctionSpec, RuntimeKind};
use junctiond_repro::runtime::{default_artifacts_dir, rustcrypto_aes_ctr, Executor};
use junctiond_repro::simcore::{Sim, MICROS, SECONDS};

fn main() -> anyhow::Result<()> {
    // ---- 1. Real compute: load the AOT artifact and encrypt something.
    let exec = Executor::load(&default_artifacts_dir())?;
    let plaintext = *b"the quick brown fox jumps over the lazy dog... padding padding!!";
    let mut pt600 = [0u8; 600];
    pt600[..plaintext.len()].copy_from_slice(&plaintext);
    let key = *b"junctiond-quick!";
    let nonce = [1u8; 12];
    let ct = exec.aes600(&pt600, &key, &nonce)?;
    assert_eq!(ct.to_vec(), rustcrypto_aes_ctr(&pt600, &key, &nonce));
    println!("PJRT aes600 OK — first ciphertext bytes: {:02x?}", &ct[..8]);

    // ---- 2. The FaaS runtime: deploy + invoke on both backends.
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let cfg = ExperimentConfig { backend, ..Default::default() };
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        let cold = fs.deploy(&mut sim, FunctionSpec::new("aes", "aes600", RuntimeKind::Go));
        println!("\n[{}] deployed 'aes' (cold start {:.2} ms)", backend.name(), cold as f64 / 1e6);
        sim.run_until(SECONDS);
        // Sequential invocations (submit the next when the previous lands).
        let lat = Rc::new(RefCell::new(Vec::new()));
        fn chain(sim: &mut Sim, fs: FaasSim, lat: Rc<RefCell<Vec<u64>>>, left: u32) {
            if left == 0 {
                return;
            }
            let fs2 = fs.clone();
            fs.submit(sim, "aes", move |sim, t| {
                lat.borrow_mut().push(t.gateway_observed());
                chain(sim, fs2, lat.clone(), left - 1);
            });
        }
        chain(&mut sim, fs.clone(), lat.clone(), 10);
        sim.run_to_completion();
        let lats: Vec<String> =
            lat.borrow().iter().map(|&ns| format!("{:.0}µs", ns as f64 / MICROS as f64)).collect();
        println!("[{}] 10 warm invocations (gateway-observed): {}", backend.name(), lats.join(" "));
    }
    println!("\nquickstart OK");
    Ok(())
}
