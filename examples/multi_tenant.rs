//! Multi-tenant trace replay (the paper's §1 motivation): a skewed
//! population of functions — a few hot, most rarely invoked [22] — with
//! scale-from-zero deploys, containerd vs junctiond.
//!
//! ```sh
//! cargo run --release --example multi_tenant
//! ```

use junctiond_repro::experiments as ex;

fn main() {
    let table = ex::multitenant_table(60, 1_000.0, 9);
    println!("{}", table.to_markdown());
    println!("containerd's tail is cold-start dominated (~250 ms container boots);");
    println!("junctiond starts instances in ~3.4 ms, so even first-touch invocations");
    println!("stay in the millisecond range — the paper's density argument in action.");
}
