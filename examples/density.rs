//! Density / polling-core ablation (paper §3): Junction's scheduler polls
//! for *all* instances from one dedicated core, so hosting thousands of
//! functions reserves one core; DPDK-style bypass needs one polling core
//! per isolated function and stops fitting on the box two orders of
//! magnitude earlier.
//!
//! ```sh
//! cargo run --release --example density
//! ```

use junctiond_repro::experiments as ex;

fn main() {
    let table = ex::ablation_polling_table(&[1, 4, 16, 64, 256, 1024, 4096], 2);
    println!("{}", table.to_markdown());
    println!("paper §3: \"Junction can use a single dedicated core to manage");
    println!("thousands of functions on a 36-core server\" — the junction p99");
    println!("column staying flat as the population grows is that claim.");
}
