//! Cold-start comparison (paper §5 "Cold starts"): Junction instance init
//! (paper: 3.4 ms) vs containerd container start, plus the latency of the
//! first invocation after deploy.
//!
//! ```sh
//! cargo run --release --example coldstart
//! ```

use junctiond_repro::experiments as ex;

fn main() {
    let table = ex::coldstart_table(100, 5);
    println!("{}", table.to_markdown());
    println!("paper: \"Junction takes 3.4 ms to initialize\" a single-threaded instance;");
    println!("containerd cold starts are hundreds of ms (image present, no pull).");
}
