//! Cold-start comparison (paper §5 "Cold starts"), extended with the
//! tiered provisioning ladder: besides the paper's single number
//! (Junction instance init ≈ 3.4 ms vs containerd's ~250 ms boot), every
//! function can now be provisioned from a warm-paused pool (near-zero) or
//! restored from a per-function memory snapshot (≪ cold boot) — on both
//! backends, with the paper's 10–100× gap preserved at every rung.
//!
//! ```sh
//! cargo run --release --example coldstart
//! ```

use std::rc::Rc;

use junctiond_repro::config::{Backend, PlatformConfig};
use junctiond_repro::experiments as ex;
use junctiond_repro::faas::FaasSim;
use junctiond_repro::simcore::{Sim, MILLIS, SECONDS};
use junctiond_repro::workload::{replay_with_keepalive, TraceGenerator};

fn main() {
    // The paper's E3: instance init + first-invocation latency.
    let table = ex::coldstart_table(100, 5);
    println!("{}", table.to_markdown());
    println!("paper: \"Junction takes 3.4 ms to initialize\" a single-threaded instance;");
    println!("containerd cold starts are hundreds of ms (image present, no pull).\n");

    // The provisioning ladder: warm pool / snapshot restore / cold boot.
    let tiers = ex::coldstart_tiers_table(50, 5);
    println!("{}", tiers.to_markdown());
    println!("warm-pool unparks a kept-alive instance, snapshot-restore rebuilds one");
    println!("from the per-function memory snapshot captured after first boot, and");
    println!("cold-boot is the seed's full path. junctiond wins every rung.\n");

    // What the ladder buys under a bursty, skewed multi-tenant trace with
    // keep-alive scale-to-zero (the FaaSNet/Shahrad scenario).
    println!("tier mix under a bursty trace (16 functions, 100 rps, 8 s, keep-alive 100 ms):");
    for backend in [Backend::Containerd, Backend::Junctiond] {
        let cfg = ex::standard_config(backend, 5);
        let mut sim = Sim::new();
        let fs = FaasSim::new(&cfg, Rc::new(PlatformConfig::default()));
        let mut pc = fs.pool_config();
        pc.idle_ttl_ns = 300 * MILLIS;
        fs.set_pool_config(pc);
        fs.start_pool_maintenance(&mut sim, 100 * MILLIS, 20 * SECONDS);
        let events = TraceGenerator::new(16, 100.0, 5).generate(8 * SECONDS);
        let mut r = replay_with_keepalive(&mut sim, &fs, &events, 16, 100 * MILLIS, |i| {
            format!("fn-{i}")
        });
        println!(
            "  {:<11} p50 {:>8.2} ms  p99 {:>8.2} ms   provisions warm/restore/cold = {}/{}/{}",
            backend.name(),
            r.latency.quantile(0.5) as f64 / 1e6,
            r.latency.quantile(0.99) as f64 / 1e6,
            r.provisions[0],
            r.provisions[1],
            r.provisions[2],
        );
    }
    println!("\nthe warm and snapshot rungs keep re-provisioning off the tail — junctiond's");
    println!("rungs are 10–100× cheaper than containerd's, so its tail stays in the ms range.");
}
