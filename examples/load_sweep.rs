//! Figure 6 reproduction: gateway-observed response time vs offered load,
//! both backends, plus the sustainable-throughput knee ratio (the paper's
//! "10× more throughput while lowering latency ~2× median / ~3.5× tail").
//!
//! ```sh
//! cargo run --release --example load_sweep
//! ```

use junctiond_repro::config::Backend;
use junctiond_repro::experiments as ex;
use junctiond_repro::simcore::{MILLIS, SECONDS};

fn main() {
    let rates = ex::fig6_default_rates();
    let (table, points) = ex::fig6_table(&rates, SECONDS, 3);
    println!("{}", table.to_markdown());

    let sla = 5 * MILLIS;
    let kc = ex::knee(&points, Backend::Containerd, sla);
    let kj = ex::knee(&points, Backend::Junctiond, sla);
    println!("sustainable throughput (p99 ≤ 5 ms):");
    println!("  containerd: {kc:>9.0} rps");
    println!("  junctiond : {kj:>9.0} rps   ({:.1}×; paper: ~10×)", kj / kc.max(1.0));

    // Pre-knee latency ratios at the highest load containerd sustains.
    let at = |b: Backend| points.iter().filter(|p| p.backend == b && p.offered_rps <= kc).last();
    if let (Some(c), Some(j)) = (at(Backend::Containerd), at(Backend::Junctiond)) {
        println!(
            "at {} rps: median {:.1}× lower, p99 {:.1}× lower (paper: ~2× / ~3.5×)",
            c.offered_rps,
            c.p50 as f64 / j.p50 as f64,
            c.p99 as f64 / j.p99 as f64
        );
    }
}
